"""Deployment builder + closed-loop clients: wires Sim, Network, replicas,
LBs, Controller, policies into the named system variants evaluated in the
paper (Fig. 8/9/10) plus our beyond-paper variants.

Variants:
  skylb        LB/region, prefix-trie local + snapshot-trie remote, SP-P
  skylb-ch     LB/region, consistent hashing at both layers, SP-P
  rr/ll/ch/sgl single central LB (US), blind pushing  — paper baselines
  gke          LB/region, RR, outstanding-cap spillover to remote regions
               (GKE-Gateway-like: no prefix awareness, no pending probes)
  region-local LB/region, least-load, NO cross-region  — Fig. 10 baseline
  blend        BEYOND-PAPER: skylb with blended prefix x load scoring
  steal        BEYOND-PAPER: skylb + receiver-initiated work stealing
  sp-o / bp    skylb trie routing but SP-O / blind pushing (Fig. 9 ablation)
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Optional

from repro.core.metrics import RunMetrics
from repro.core.simulator import (Controller, LoadBalancerSim, Network,
                                  ReplicaConfig, ReplicaSim, Request, Sim,
                                  resolve_cancelled)
from repro.core.workloads import SessionSpec, TreeSpec, _tokens, stable_hash
from repro.frontend.api import RequestHandle, RequestState
from repro.frontend.client import state_of
from repro.routing import build_routing
from repro.serving.request import (FinishReason, GenResult,
                                   cancel_finish_reason, next_rid)

REGIONS = ("us", "eu", "asia")


class ServingSystem:
    def __init__(self, variant: str, replicas_per_region: dict[str, int],
                 *, replica_cfg: ReplicaConfig = ReplicaConfig(),
                 net: Optional[Network] = None, seed: int = 0,
                 cfg_overrides: Optional[dict] = None):
        self.sim = Sim()
        self.net = net or Network()
        self.variant = variant
        self.metrics = RunMetrics()
        self.replicas: list[ReplicaSim] = []
        self._region_of: dict[str, str] = {}    # rid -> region (O(1) lookups)
        self.lbs: dict[str, LoadBalancerSim] = {}
        self._rid = itertools.count()
        # request ids come from the ONE process-wide counter shared with
        # GenRequest, so frontend-submitted and internal-client requests
        # can never collide in the rid-keyed cancel/deadline registries
        self._req_id = iter(next_rid, None)
        self._inflight: dict[int, Request] = {}   # rid -> unresolved request
        self.rng = random.Random(seed)
        self.replica_cfg = replica_cfg          # template for elastic adds
        # RoutingConfig field overrides for every LB this system builds
        # (e.g. fairness=True, slo_lanes=True, admission=True for the
        # multi-tenant scenarios) — same shape as LBSpec.cfg_overrides on
        # the socket plane
        self.cfg_overrides = dict(cfg_overrides or {})
        self._build(variant, replicas_per_region, replica_cfg)
        self.controller = Controller(self.sim, self.net,
                                     list(self.lbs.values()))

    # ------------------------------------------------------------ build
    def _mk_replica(self, region: str, cfg: ReplicaConfig) -> ReplicaSim:
        r = ReplicaSim(self.sim, f"{region}-r{next(self._rid)}", region,
                       dataclasses.replace(cfg))
        r.on_bounce = lambda req, rep=r: self._bounce(rep, req)
        self.replicas.append(r)
        self._region_of[r.id] = region
        return r

    def _mk_replicas(self, region: str, n: int, cfg: ReplicaConfig):
        return [self._mk_replica(region, cfg) for _ in range(n)]

    def _build(self, variant, rpr, rcfg):
        spec = build_routing(variant)
        if spec.single_lb:
            # e.g. 'trie' = single global-view prefix-trie router (longest
            # match + least-load exploration) — the Fig. 6 'optimal' stand-in
            lb = LoadBalancerSim(self.sim, "lb-us", "us", self.net,
                                 spec.local_policy(),
                                 cfg=spec.make_config(**self.cfg_overrides),
                                 metrics=self.metrics)
            for region, n in rpr.items():
                for r in self._mk_replicas(region, n, rcfg):
                    lb.add_replica(r)
            self.lbs = {"lb-us": lb}
            return
        # one LB per region
        for region, n in rpr.items():
            lb = LoadBalancerSim(
                self.sim, f"lb-{region}", region, self.net,
                spec.local_policy(), remote_policy=spec.remote_policy(),
                cfg=spec.make_config(**self.cfg_overrides),
                metrics=self.metrics)
            for r in self._mk_replicas(region, n, rcfg):
                lb.add_replica(r)
            self.lbs[lb.id] = lb
        for a in self.lbs.values():
            for b in self.lbs.values():
                a.peer(b)

    # ------------------------------------------ elastic membership
    def lb_of(self, region: str) -> LoadBalancerSim:
        """The LB that OWNS a region's replicas (vs lb_for = nearest live).
        Single-LB variants own every region from the one central LB."""
        if len(self.lbs) == 1:
            return next(iter(self.lbs.values()))
        return self.lbs[f"lb-{region}"]

    def add_replica(self, region: str,
                    cfg: Optional[ReplicaConfig] = None) -> ReplicaSim:
        """A replica joins at runtime: registered with its region's LB
        (fresh TargetView — routable before the next probe)."""
        r = self._mk_replica(region, cfg or self.replica_cfg)
        self.lb_of(region).add_replica(r)
        return r

    def drain_replica(self, rid: str, on_drained=None) -> ReplicaSim:
        """Graceful decommission: leave the routing tables NOW (prefix-trie
        records / hashring vnodes forgotten once, no new admissions), finish
        in-flight work, then fire on_drained(replica). The replica stays in
        self.replicas so its stats survive into the run summary."""
        owner = next((lb for lb in self.lbs.values() if rid in lb.replicas),
                     None)
        r = (owner.remove_replica(rid) if owner is not None
             else next((x for x in self.replicas if x.id == rid), None))
        if r is None:
            raise ValueError(f"unknown replica {rid!r}")
        r.drain(on_drained)
        return r

    def _bounce(self, replica: ReplicaSim, req: Request) -> None:
        """A request reached a replica after its drain began (it was on the
        wire when admission stopped): hand it back to the nearest live LB
        for a fresh routing decision rather than dropping it."""
        req.forwarded = False
        req.replica = None
        lb = self.lb_for(replica.region)
        self.sim.after(self.net.one_way(replica.region, lb.region),
                       lambda: lb.on_request(req))

    # ------------------------------------------------------------ routing
    def lb_for(self, region: str) -> LoadBalancerSim:
        """DNS resolution: nearest live LB (paper §4.1)."""
        live = [lb for lb in self.lbs.values() if lb.alive]
        return min(live, key=lambda lb: self.net.one_way(region, lb.region))

    def _back_delay(self, r: Request) -> float:
        """Replica -> client one-way (client-observed event times)."""
        return self.net.one_way(
            self._region_of.get(r.replica, r.region), r.region)

    def _result_state(self, r: Request,
                      handle: RequestHandle) -> tuple[GenResult, RequestState]:
        if r.error is not None:
            reason = FinishReason.ABORT
        elif r.finish_reason is not None:
            reason = cancel_finish_reason(r.finish_reason)
        else:
            reason = FinishReason.LENGTH
        state = state_of(reason)
        res = GenResult(
            rid=r.rid, output_tokens=handle.tokens, finish_reason=reason,
            cached_tokens=r.cached_tokens, prompt_len=len(r.prompt_tokens),
            ttft_s=(r.ttft - r.issued) if r.ttft is not None else None,
            e2e_s=((r.finished - r.issued) if r.finished is not None
                   else None),
            error=r.error)
        return res, state

    def submit(self, req: Request, done_cb=None, *,
               handle: RequestHandle = None) -> RequestHandle:
        """The front door: submit returns a `RequestHandle` exposing the
        token-event stream (client-observed times: replica->client WAN
        delay included), `cancel()`, and the terminal `GenResult`.

        `done_cb` is the LEGACY callback surface, kept as a thin shim over
        the handle: it still receives the raw sim `Request` at the same
        event the handle resolves. `handle` lets `repro.frontend.SimHost`
        pass the client-owned handle in so there is exactly one per
        request."""
        if handle is None:
            handle = RequestHandle(
                req, canceller=lambda h: self.cancel(h.rid, "cancelled"),
                pump=lambda: self.sim.run(max_events=1) > 0)
        if done_cb is not None:
            handle.on_done(lambda _res, r=req, cb=done_cb: cb(r))
        req.issued = self.sim.now
        self.metrics.on_issued(req)
        self._inflight[req.rid] = req

        def finish(r: Request):
            res, state = self._result_state(r, handle)
            # one zero-delay event, exactly where the legacy done_cb fired
            self.sim.after(0.0, lambda: handle._finish(res, state))

        def wrapped_admit(r: Request, t: float):
            handle._admit(t + self._back_delay(r))

        def wrapped_token(r: Request, tok: int, idx: int, t: float):
            handle._token(tok, idx, t + self._back_delay(r))

        def wrapped_done(r: Request):
            self._inflight.pop(r.rid, None)
            if r.error is not None:     # replica rejected (oversized)
                self.metrics.on_rejected(r)
            elif r.finish_reason == "cancelled":
                self.metrics.on_cancelled(r)
            elif r.finish_reason == "deadline":
                self.metrics.on_deadline(r)
            elif r.finish_reason == "shed":
                self.metrics.on_shed(r)
            else:
                back = self._back_delay(r)
                if r.ttft is not None:
                    r.ttft += back      # client-observed first token
                r.finished += back
                self.metrics.on_done(r)
            finish(r)
            # break the retention chain req -> callbacks -> handle ->
            # events: metrics keep the request for the whole run, and an
            # internal client's handle (with one TokenEvent per generated
            # token) must not be pinned along with it
            r.admit_cb = r.token_cb = r.done_cb = None

        req.admit_cb = wrapped_admit
        req.token_cb = wrapped_token
        req.done_cb = wrapped_done
        if req.deadline_s is not None and req.deadline_s <= 0:
            # expired before admission: terminal DEADLINE, nothing
            # dispatched — no LB ever sees the request
            req.finish_reason = "deadline"
            req.finished = self.sim.now
            self._inflight.pop(req.rid, None)
            self.metrics.on_deadline(req)
            finish(req)
            return handle
        lb = self.lb_for(req.region)
        self.sim.after(self.net.one_way(req.region, lb.region),
                       lambda: lb.on_request(req))
        if req.deadline_s is not None:
            self.sim.at(req.issued + req.deadline_s,
                        lambda: self.cancel(req.rid, "deadline"))
        return handle

    # ------------------------------------------------------------ cancel
    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Propagate a cancel to wherever the request is right now: an LB
        queue, a replica (pending or mid-decode — pages and radix pins are
        freed), or the WAN (forward / steal / failover handoff in flight —
        the flag travels on the request object and the next host to see it
        resolves it, so a cancel racing a steal resolves exactly once).
        Returns False when the request is already terminal (cancel after
        finish is a no-op) or was already cancelled."""
        req = self._inflight.get(rid)
        if req is None or req.finished is not None or req.cancelled is not None:
            return False
        req.cancelled = reason
        for lb in self.lbs.values():
            got = lb.core.cancel(rid)
            if got is not None:         # still queued at this LB
                resolve_cancelled(got, self.sim.now, reason)
                return True
        for r in self.replicas:
            if r.cancel(rid) is not None:
                return True
        return True     # on the WAN: resolved once, at the next arrival

    # ------------------------------------------------------------ clients
    # The closed-loop clients drive the NEW front API: submit returns a
    # RequestHandle and the next turn is chained on its terminal GenResult.
    def add_session_client(self, spec: SessionSpec,
                           think_mean: float = 1.0) -> None:
        state = {"i": 0, "history": tuple(spec.system_prompt)}

        def issue():
            i = state["i"]
            if i >= len(spec.turns):
                return
            turn = spec.turns[i]
            prompt = state["history"] + tuple(turn.prompt_suffix)
            req = Request(
                rid=next(self._req_id), user_id=spec.user_id,
                session_key=spec.user_id, region=spec.region,
                prompt_tokens=prompt, output_len=len(turn.output_tokens),
                output_tokens=tuple(turn.output_tokens))
            self.submit(req).on_done(lambda res: done(res, prompt, turn))

        def done(res: GenResult, prompt: tuple, turn):
            if res.error is not None:
                # replica rejected the turn (oversized): the history only
                # grows, so every later turn would fail too — end the session
                return
            state["history"] = prompt + tuple(turn.output_tokens)
            state["i"] += 1
            think = self.rng.expovariate(1.0 / max(1e-6, think_mean))
            self.sim.after(think, issue)

        self.sim.after(self.rng.uniform(0, 0.5), issue)

    def add_tot_client(self, trees: list[TreeSpec]) -> None:
        state = {"ti": 0}

        def run_tree():
            if state["ti"] >= len(trees):
                return
            tree = trees[state["ti"]]
            trng = random.Random(tree.seed)
            thoughts: dict[tuple, tuple] = {}
            aborted = {"v": False}

            def node_prompt(path: tuple) -> tuple:
                """question + thoughts of all ANCESTORS (root .. parent)."""
                prompt = tuple(tree.question)
                for d in range(len(path)):
                    prompt += thoughts[path[:d]]
                return prompt

            def issue_layer(depth: int, frontier: list[tuple]):
                if depth >= tree.depth:
                    state["ti"] += 1
                    self.sim.after(0.5, run_tree)
                    return
                left = {"n": len(frontier)}
                children: list[tuple] = []

                def one_done(path):
                    def cb(res: GenResult):
                        if aborted["v"]:
                            return
                        if res.error is not None:
                            # a rejected node breaks the tree's prefix chain:
                            # abandon this tree, move on to the next one
                            aborted["v"] = True
                            state["ti"] += 1
                            self.sim.after(0.5, run_tree)
                            return
                        thoughts[path] = tuple(res.output_tokens)
                        for b in range(tree.branching):
                            children.append(path + (b,))
                        left["n"] -= 1
                        if left["n"] == 0:
                            issue_layer(depth + 1, children)
                    return cb

                for path in frontier:
                    rng = random.Random(stable_hash(tree.seed, path))
                    olen = tree.node_output_len(path)
                    out = _tokens(rng, olen)
                    req = Request(
                        rid=next(self._req_id), user_id=tree.user_id,
                        session_key=f"{tree.user_id}:{tree.seed}",
                        region=tree.region, prompt_tokens=node_prompt(path),
                        output_len=olen, output_tokens=out)
                    self.submit(req).on_done(one_done(path))

            issue_layer(0, [()])

        self.sim.after(self.rng.uniform(0, 0.5), run_tree)

    def add_open_loop(self, region: str, rate_fn, until: float, *,
                      prompt_len: int = 96, output_len: int = 48,
                      template_len: int = 48, seed: int = 0) -> None:
        """OPEN-loop arrivals for one region: a non-homogeneous Poisson
        process at `rate_fn(sim_now)` requests/sim-second (piecewise
        approximation: the rate is sampled when each gap is drawn — fine
        for diurnal curves that move over hours, not seconds). Prompts
        share a per-region template prefix; the suffix is unique. This is
        the demand side of the elastic-provisioning scenarios (fig11),
        where load must vary with the clock rather than with client
        think-time."""
        rng = random.Random(stable_hash(seed, region, "openloop"))
        template = _tokens(rng, template_len)

        def arrive():
            if self.sim.now >= until:
                return
            rid = next(self._req_id)
            req = Request(
                rid=rid, user_id=f"{region}-open", session_key=f"{region}-o{rid}",
                region=region, prompt_tokens=template + _tokens(rng, prompt_len),
                output_len=output_len, output_tokens=_tokens(rng, output_len))
            self.submit(req)
            self.sim.after(rng.expovariate(max(1e-9, rate_fn(self.sim.now))),
                           arrive)

        self.sim.after(rng.expovariate(max(1e-9, rate_fn(self.sim.now))),
                       arrive)

    def add_tenant_load(self, region: str, rate: float, until: float, *,
                        deadline_s: Optional[float] = None,
                        slo_class: str = "standard", stream=None,
                        seed: int = 0, **stream_kw) -> None:
        """OPEN-loop per-tenant arrivals at a constant Poisson `rate`,
        with tenants drawn from `workloads.tenant_request_stream` (Zipf
        over user_id: few abusive cache-affine tenants, many light) — the
        demand side of the fairness scenarios (fig12). `session_key` is
        the tenant, so affinity policies concentrate each tenant's traffic
        exactly the way the abuse pattern needs."""
        from repro.core.workloads import tenant_request_stream
        rng = random.Random(stable_hash(seed, region, "tenantload"))
        gen = stream if stream is not None else tenant_request_stream(
            region, seed=seed, **stream_kw)

        def arrive():
            if self.sim.now >= until:
                return
            uid, prompt, olen = next(gen)
            req = Request(
                rid=next(self._req_id), user_id=uid, session_key=uid,
                region=region, prompt_tokens=prompt, output_len=olen,
                output_tokens=_tokens(rng, olen),
                deadline_s=deadline_s, slo_class=slo_class)
            self.submit(req)
            self.sim.after(rng.expovariate(max(1e-9, rate)), arrive)

        self.sim.after(rng.expovariate(max(1e-9, rate)), arrive)

    # ------------------------------------------------------------ run
    def run(self, until: float) -> dict:
        self.metrics.t_start = 0.0
        self.sim.run(until=until)
        self.metrics.t_end = min(self.sim.now, until)
        return self.metrics.summary(self.replicas)
