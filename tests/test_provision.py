"""Elastic provisioning subsystem: analytic cost model (ragged-series
regression), measured CostMeter, scaler policies, FleetController
lifecycle, drain-vs-kill semantics, and the region-outage drill."""
from __future__ import annotations

import pytest

from repro.core.simulator import ReplicaConfig
from repro.core.system import ServingSystem
from repro.core.workloads import diurnal_series
from repro.provision import (ON_DEMAND, RESERVED, CostMeter, FleetController,
                             ForecastBurst, GlobalPeakReserved,
                             PerRegionPeakReserved, autoscale_on_demand_cost,
                             global_peak, global_peak_cost, region_local_cost,
                             replicas_needed, variance_stats)
from repro.provision.cost import ON_DEMAND_RATE, RESERVED_RATE

RCFG = ReplicaConfig(kv_budget=8192)


def _req(sys, rid, region="us", prompt_len=32, out_len=8, user="u"):
    from repro.core.simulator import Request
    return Request(rid=rid, user_id=user, session_key=f"{user}{rid}",
                   region=region, prompt_tokens=tuple(range(prompt_len)),
                   output_len=out_len, output_tokens=tuple(range(out_len)))


# --------------------------------------------------- analytic cost model

def test_cost_ragged_series_rejected():
    """Regression: series[r][i] indexing assumed equal lengths — ragged
    input used to IndexError (short first region) or silently drop samples
    (short later region). Now it fails loudly."""
    ragged = {"us": [1.0, 2.0, 3.0], "eu": [1.0, 2.0]}
    with pytest.raises(ValueError, match="ragged"):
        global_peak_cost(ragged, kappa=1.0)
    with pytest.raises(ValueError, match="ragged"):
        variance_stats(ragged)
    with pytest.raises(ValueError):
        global_peak_cost({}, kappa=1.0)
    with pytest.raises(ValueError):
        global_peak_cost({"us": []}, kappa=1.0)


def test_cost_ragged_series_ok_where_no_aggregation():
    """Per-region integrals don't need a shared grid: each region's step is
    hours/len(xs), so a coarser region still integrates the same window."""
    fine = {"us": [2.0] * 24, "eu": [1.0] * 24}
    coarse = {"us": [2.0] * 24, "eu": [1.0] * 12}     # eu at 2 h steps
    a = autoscale_on_demand_cost(fine, kappa=1.0, hours=24.0)
    b = autoscale_on_demand_cost(coarse, kappa=1.0, hours=24.0)
    assert a == pytest.approx(b)
    # region-local peaks never cross-index either
    assert region_local_cost(coarse, kappa=1.0) == \
        pytest.approx(region_local_cost(fine, kappa=1.0))


def test_core_cost_shim_reexports():
    import repro.core.cost as shim
    assert shim.global_peak_cost is global_peak_cost
    assert shim.RESERVED_RATE == RESERVED_RATE


# --------------------------------------------------------- cost meter

def test_cost_meter_integrates_replica_hours():
    m = CostMeter(sim_s_per_h=2.0)
    m.on_start("r0", RESERVED, "us", t=0.0)
    m.on_start("r1", ON_DEMAND, "us", t=1.0)
    m.on_stop("r1", t=5.0)                    # 4 sim-s = 2 h on-demand
    hours = m.replica_hours(until=8.0)        # r0 still live: 8 sim-s = 4 h
    assert hours[RESERVED] == pytest.approx(4.0)
    assert hours[ON_DEMAND] == pytest.approx(2.0)
    d = m.dollars(until=8.0)
    assert d["total"] == pytest.approx(
        4.0 * RESERVED_RATE + 2.0 * ON_DEMAND_RATE)
    # $/day normalizes by simulated hours (4 h window here)
    s = m.summary(until=8.0)
    assert s["cost_usd_per_day"] == pytest.approx(d["total"] * 6.0, rel=1e-6)
    with pytest.raises(ValueError):
        m.on_start("r0", RESERVED, "us", t=9.0)    # double meter
    with pytest.raises(ValueError):
        m.on_start("rX", "spot", "us", t=0.0)      # unknown tier


# ----------------------------------------------------------- scalers

def _forecast(region, hour):
    from repro.core.workloads import diurnal_rate
    amps = {"us": 1.0, "eu": 0.8, "asia": 0.9}
    return 10.0 * diurnal_rate(region, hour % 24.0, amp=amps[region])


REGIONS3 = ("us", "eu", "asia")


def test_static_scalers_match_analytic_model():
    per = PerRegionPeakReserved(_forecast, 2.0, REGIONS3)
    glob = GlobalPeakReserved(_forecast, 2.0, REGIONS3)
    n_per = sum(per.desired(r, 0.0)[RESERVED] for r in REGIONS3)
    n_glob = sum(glob.desired(r, 0.0)[RESERVED] for r in REGIONS3)
    assert n_glob == max(
        replicas_needed(global_peak(_forecast, REGIONS3), 2.0), len(REGIONS3))
    assert n_glob < n_per                   # offset peaks aggregate flatter
    assert all(glob.desired(r, 0.0)[RESERVED] >= 1 for r in REGIONS3)
    # static: same answer at any hour
    assert per.desired("us", 3.0) == per.desired("us", 17.0)


def test_forecast_burst_tracks_demand_with_lead():
    fb = ForecastBurst(_forecast, 2.0, REGIONS3, lead_h=0.5, headroom=1.0)
    floor = fb.desired("us", 0.0)[RESERVED]
    assert floor == replicas_needed(
        min(_forecast("us", h / 4) for h in range(96)), 2.0)
    # us peaks at local 14:00: burst capacity wanted on the ramp, none at
    # the trough
    assert fb.desired("us", 13.0)[ON_DEMAND] > 0
    assert fb.desired("us", 2.0)[ON_DEMAND] == 0
    # lead: desired at H answers for the forecast at H + lead
    want_led = replicas_needed(_forecast("us", 12.5), 2.0)
    got = fb.desired("us", 12.0)
    assert got[RESERVED] + got[ON_DEMAND] == max(want_led, floor)


# ------------------------------------------------- fleet controller

class _StepScaler:
    """1 reserved always; 2 on-demand during hours [1, 2)."""
    name = "step"
    regions = ("us",)

    def desired(self, region, hour):
        return {RESERVED: 1, ON_DEMAND: 2 if 1.0 <= hour < 2.0 else 0}


def test_fleet_controller_scales_up_and_drains_down():
    sys = ServingSystem("skylb", {"us": 0}, replica_cfg=RCFG)
    fleet = FleetController(sys, _StepScaler(), sim_s_per_h=1.0,
                            eval_interval_s=0.25, provision_delay_h=0.2)
    lb = sys.lbs["lb-us"]
    sizes = []
    probe = lambda: (sizes.append((sys.sim.now, len(lb.replicas))),
                     sys.sim.after(0.1, probe))
    sys.sim.after(0.0, probe)
    sys.run(until=4.0)
    by_t = dict(sizes)
    assert by_t[0.0] == 1                       # reserved up at t=0, no delay
    # on-demand wanted at hour 1, arrives ~0.2 h later, drains after hour 2
    assert max(n for t, n in sizes if 1.5 <= t < 2.0) == 3
    assert by_t[max(by_t)] == 1                 # drained back to the floor
    cost = fleet.finalize()
    assert cost["cost_usd_reserved"] > 0
    assert cost["cost_usd_on_demand"] > 0
    # on-demand billed from REQUEST to drain-complete: >= the 1 h window
    assert cost["replica_hours_on_demand"] >= 2 * 1.0
    assert sys.metrics.cost is cost


def test_fleet_scale_down_drains_inflight_to_completion():
    """Scale-down during load must not lose the drained replica's work."""
    sys = ServingSystem("skylb", {"us": 0}, replica_cfg=RCFG)
    fleet = FleetController(sys, _StepScaler(), sim_s_per_h=1.0,
                            eval_interval_s=0.25, provision_delay_h=0.0)
    done = []
    # steady trickle across the scale-up/down boundary
    def issue(i=0):
        if i >= 40:
            return
        sys.submit(_req(sys, i, out_len=16), done.append)
        sys.sim.after(0.1, lambda: issue(i + 1))
    sys.sim.after(0.0, issue)
    sys.run(until=30.0)
    assert len(done) == 40
    assert all(r.error is None for r in done)
    assert sys.metrics.issued == 40
    assert fleet.finalize()["cost_usd_on_demand"] > 0


# ------------------------------------------- drain vs kill semantics

def _counting_policy(lb):
    removed = []
    orig = lb.core.policy.on_target_removed
    lb.core.policy.on_target_removed = lambda tid: (removed.append(tid),
                                                    orig(tid))[1]
    return removed


def test_drain_finishes_inflight_rejects_new_forgets_once():
    sys = ServingSystem("skylb", {"us": 2}, replica_cfg=RCFG)
    lb = sys.lbs["lb-us"]
    removed = _counting_policy(lb)
    victim = sys.replicas[0]
    done, drained = [], []
    # load BOTH replicas so the victim holds in-flight work when drained
    for i in range(8):
        sys.submit(_req(sys, i, out_len=24), done.append)
    sys.sim.after(0.5, lambda: sys.drain_replica(victim.id,
                                                 on_drained=drained.append))
    sys.run(until=60.0)
    assert drained == [victim]                  # drain completed, once
    assert not victim.alive and not victim.draining
    assert victim.completions > 0               # it did finish its work
    assert len(done) == 8
    assert all(r.error is None for r in done)   # nothing dropped or errored
    # routing state forgotten exactly ONCE despite later no-op removals
    assert removed == [victim.id]
    lb.remove_replica(victim.id)                # idempotent repeat
    assert removed == [victim.id]
    # trie holds no stale record of the drained target
    tree = lb.core.policy.tree
    assert all(victim.id not in n.targets
               for n in tree.root.children.values())
    # new work after the drain never lands on the drained replica
    late = []
    for i in range(20, 24):
        sys.submit(_req(sys, i), late.append)
    sys.run(until=120.0)
    assert len(late) == 4
    assert all(r.replica == sys.replicas[1].id for r in late)


def test_drain_vs_kill_inflight_contrast():
    def run_one(stop):
        sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
        done = []
        sys.submit(_req(sys, 0, out_len=40), done.append)
        sys.sim.after(0.3, lambda: stop(sys))
        sys.run(until=60.0)
        return done
    # drain: the in-flight decode finishes
    finished = run_one(lambda s: s.drain_replica(s.replicas[0].id))
    assert len(finished) == 1 and finished[0].error is None
    # kill: the in-flight decode is lost (crash semantics)
    lost = run_one(lambda s: s.replicas[0].kill())
    assert lost == []


def test_kill_during_drain_still_fires_drain_callback():
    """A crash hitting a replica mid-drain must complete the drain
    vacuously — otherwise the fleet lease (and its bill) stays open."""
    sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
    r = sys.replicas[0]
    drained = []
    sys.submit(_req(sys, 0, out_len=40), lambda x: None)   # in-flight work
    sys.sim.after(0.3, lambda: sys.drain_replica(r.id,
                                                 on_drained=drained.append))
    sys.sim.after(0.4, r.kill)                  # crash before drain finishes
    sys.run(until=30.0)
    assert drained == [r]
    assert not r.alive and not r.draining


def test_scale_down_cancels_pending_spinup_before_draining_live():
    """A spin-up that becomes unwanted while still provisioning is
    cancelled (free) rather than letting it land, billing from request,
    and draining a LIVE replica in its place."""
    class Blip:
        name = "blip"
        regions = ("us",)

        def desired(self, region, hour):
            # on-demand wanted only for a 0.3 h window, shorter than the
            # 1.0 h provisioning delay
            return {RESERVED: 1, ON_DEMAND: 2 if 1.0 <= hour < 1.3 else 0}

    sys = ServingSystem("skylb", {"us": 0}, replica_cfg=RCFG)
    fleet = FleetController(sys, Blip(), sim_s_per_h=1.0,
                            eval_interval_s=0.1, provision_delay_h=1.0)
    sys.run(until=5.0)
    assert len(sys.lbs["lb-us"].replicas) == 1      # blip never materialized
    assert len(sys.replicas) == 1                   # no on-demand ever built
    cost = fleet.finalize()
    assert cost["cost_usd_on_demand"] == 0          # cancelled == unbilled
    assert fleet.fleet_counts("us") == {RESERVED: 1, ON_DEMAND: 0}


def test_drain_of_dead_replica_completes_vacuously():
    """Drain after a crash must still fire its callback (the fleet
    controller would otherwise hold the lease — and the bill — open)."""
    sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
    r = sys.replicas[0]
    r.kill()
    drained = []
    sys.drain_replica(r.id, on_drained=drained.append)
    sys.run(until=5.0)
    assert drained == [r]
    assert not r.alive and not r.draining


def test_enqueue_on_draining_replica_bounces_back():
    """A request on the wire when the drain begins is re-routed, not
    dropped: the fleet system points the replica's bounce hook at a live
    LB."""
    sys = ServingSystem("skylb", {"us": 2}, replica_cfg=RCFG)
    a, b = sys.replicas
    done = []
    req = _req(sys, 0)
    # hand the request DIRECTLY to a draining replica, as if it had been
    # dispatched just before the drain started
    req.done_cb = done.append
    sys.drain_replica(a.id)
    a.enqueue(req)
    sys.run(until=60.0)
    assert len(done) == 1 and done[0].error is None
    assert done[0].replica == b.id


def test_hashring_variant_forgets_drained_target_once():
    sys = ServingSystem("skylb-ch", {"us": 2}, replica_cfg=RCFG)
    lb = sys.lbs["lb-us"]
    removed = _counting_policy(lb)
    victim = sys.replicas[0]
    for i in range(4):
        sys.submit(_req(sys, i), lambda r: None)
    sys.sim.after(0.2, lambda: sys.drain_replica(victim.id))
    sys.run(until=60.0)
    assert removed == [victim.id]
    assert victim.id not in lb.core.policy.ring.targets
    lb.remove_replica(victim.id)
    assert removed == [victim.id]


# --------------------------------------------------- region outage drill

def test_region_outage_reabsorbs_forwarded_inflight():
    """Drain EVERY eu replica while eu holds forwarded-in work: the one-hop
    rule is relaxed for an LB with zero live targets, so nothing is
    dropped (head-of-line work re-forwards instead of waiting forever)."""
    # tiny KV budget: ~4 concurrent sequences per replica, so us SATURATES
    # (pending > 0 at probes) and SP-P pushes the overflow to eu
    sys = ServingSystem("skylb", {"us": 1, "eu": 1},
                        replica_cfg=ReplicaConfig(kv_budget=256))
    done = []

    # arrivals faster than us capacity but slower than probes, so probes
    # SEE the backlog (all-at-once would ride the between-probe optimism
    # budget straight into the us replica's pending queue)
    def issue(i=0):
        if i >= 24:
            return
        sys.submit(_req(sys, i, out_len=24), done.append)
        sys.sim.after(0.1, lambda: issue(i + 1))

    sys.sim.after(0.0, issue)
    # then take eu out mid-run, while it still holds forwarded work
    sys.sim.after(1.0, lambda: [sys.drain_replica(r.id)
                                for r in sys.replicas if r.region == "eu"])
    s = sys.run(until=300.0)
    assert len(done) == 24
    assert all(r.error is None for r in done)
    assert s["unresolved"] == 0
    assert s["forwards"] > 0                     # eu did absorb, then return


def test_dynamic_add_replica_is_routable():
    sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
    done = []
    sys.sim.after(1.0, lambda: sys.add_replica("us"))
    def flood(i=0):
        if i >= 30:
            return
        # distinct prompts: no trie affinity, so least-load exploration is
        # free to pick the newcomer
        req = _req(sys, i, out_len=16)
        req.prompt_tokens = tuple(range(i * 100, i * 100 + 32))
        sys.submit(req, done.append)
        sys.sim.after(0.05, lambda: flood(i + 1))
    sys.sim.after(0.0, flood)
    sys.run(until=120.0)
    assert len(done) == 30
    newcomer = sys.replicas[1]
    assert any(r.replica == newcomer.id for r in done)
    assert sys._region_of[newcomer.id] == "us"
