"""Real JAX inference engine: paged KV cache + block allocator, radix-tree
prefix cache over pages, continuous-batching scheduler whose *pending queue*
is exactly what SkyLB's SP-P probes (§3.3), OpenAI-ish request types, and an
in-process multi-replica router that runs the paper's policies against real
engines.
"""
from repro.serving.blocks import BlockAllocator
from repro.serving.engine import Engine, EngineConfig
from repro.serving.radix import PagedRadixCache
from repro.serving.request import (FinishReason, GenRequest, GenResult,
                                   SamplingParams)
from repro.serving.router import InProcessRouter

__all__ = [
    "BlockAllocator", "Engine", "EngineConfig", "PagedRadixCache",
    "FinishReason", "GenRequest", "GenResult", "SamplingParams",
    "InProcessRouter",
]
