"""llama3.1-8b — the paper's OWN serving replica model
(meta-llama/Llama-3.1-8B-Instruct on L4 GPUs, SkyLB §5 setup).
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. [arXiv:2407.21783; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama31-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
    source="arXiv:2407.21783; hf (paper's serving model)",
)
