"""Merging per-process metrics snapshots into the one RunMetrics schema.

Each plane process answers ``metrics?`` with a Ray-Serve-style snapshot of
ITSELF (a replica: delivered/completed/tokens/steps; an LB: issued/
resolved/forwards/hedges).  Nobody aggregates in-band — the launcher (or a
test) sweeps the snapshots and `merge_snapshots` folds them into the same
summary dict shape `repro.core.metrics.RunMetrics.summary()` produces, so
benchmark tables and gates read identically whether a run happened in the
simulator, the in-process router, or across real PIDs.

Latency percentiles are deliberately absent here: cross-process timestamps
don't compose (per-process monotonic epochs), so TTFT/E2E distributions
belong to the CLIENT, which observes every event on one clock.  The merged
dict carries the counters that are well-defined across processes.
"""
from __future__ import annotations


def merge_snapshots(snaps: list[dict]) -> dict:
    """Fold per-process ``metrics`` snapshots into a RunMetrics-style
    summary dict (plus ``per_process`` with the raw snapshots)."""
    reps = [s for s in snaps if s.get("kind") == "replica"]
    lbs = [s for s in snaps if s.get("kind") == "lb"]
    dur = max([s.get("uptime_s", 0.0) for s in snaps], default=0.0)
    dur = max(1e-9, dur)
    out_tokens = sum(s.get("output_tokens", 0) for s in reps)
    prompt_tokens = sum(s.get("prompt_tokens", 0) for s in reps)
    cached = sum(s.get("cached_tokens", 0) for s in reps)
    completed = sum(s.get("completed", 0) for s in reps)
    issued = sum(s.get("issued", 0) for s in lbs)
    resolved = sum(s.get("resolved", 0) for s in lbs)
    return {
        "requests": completed,
        "duration_s": dur,
        "throughput_tok_s": out_tokens / dur,
        "throughput_req_s": completed / dur,
        "hit_rate": cached / max(1, prompt_tokens),
        "forwards": sum(s.get("forwarded_out", 0) for s in lbs),
        "rejected": sum(s.get("rejected", 0) for s in reps),
        "cancelled": sum(s.get("cancelled", 0) for s in reps),
        "deadline_aborted": sum(s.get("deadline_aborted", 0) for s in reps),
        "hedged": sum(s.get("hedged", 0) for s in lbs),
        "hedge_wins": sum(s.get("hedge_wins", 0) for s in lbs),
        "wasted_work_tok": sum(s.get("wasted_work_tok", 0) for s in lbs),
        "redispatched": sum(s.get("redispatched", 0) for s in lbs)
        + sum(s.get("redispatched", 0) for s in reps),
        "issued": issued,
        # issued at some LB but never resolved back through one — with the
        # caveat that client-side failover RE-issues (the client is the
        # authoritative judge for drill gates; this is the plane's view)
        "unresolved": max(0, issued - resolved),
        "steps": sum(s.get("steps", 0) for s in reps),
        # partition-tolerance counters (fence/chaos machinery)
        "fenced_frames": sum(s.get("fenced_frames", 0) for s in lbs),
        "dup_suppressed": sum(s.get("dup_suppressed", 0) for s in lbs),
        "send_drops": sum(s.get("send_drops", 0) for s in lbs),
        "kv_pull_timeouts": sum(s.get("kv_pull_timeouts", 0) for s in lbs),
        "degraded_transitions": sum(s.get("degraded_transitions", 0)
                                    for s in lbs),
        "reconnects": sum(s.get("reconnects", 0) for s in snaps),
        "fault_dropped_send": sum(s.get("fault_dropped_send", 0)
                                  for s in snaps),
        "fault_dropped_recv": sum(s.get("fault_dropped_recv", 0)
                                  for s in snaps),
        "unacked_results": sum(s.get("unacked_results", 0) for s in snaps),
        "n_processes": len(snaps),
        "per_process": list(snaps),
    }
