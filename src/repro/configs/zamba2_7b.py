"""zamba2-7b [hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone + SHARED attn blocks. [arXiv:2411.15242; unverified]

Layer mapping (DESIGN §4): 81 Mamba2 layers = 13 groups x 6 + 3 tail; ONE
shared attention+MLP block (one parameter set) applied after each group
(13 applications), zamba2's shared-block-every-6 pattern.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,               # mamba2 layers
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    # chunk=128 keeps the intra-chunk (Q x Q) SSD tensors inside per-chip HBM
    # at train_4k with 112 SSM heads (see EXPERIMENTS roofline notes)
    ssm=SSMConfig(state=64, head_dim=64, expand=2, n_groups=1, chunk=128),
    attn_every=6,
    rope_theta=10000.0,
    source="arXiv:2411.15242; unverified",
)
