"""Backend-agnostic replica scheduler core: admission edge cases shared by
both backends — fully-cached prompt (last-token re-prefill rule),
oversized-request rejection (head-of-line fix), eviction-under-pressure,
priority preemption + resume, chunked prefill, per-instance LRU clock."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.simulator import ReplicaConfig, ReplicaSim, Request, Sim
from repro.replica import (BlockAllocator, CostModelBackend, PagedRadix,
                           ReplicaBackend, ReplicaCore, ReplicaCoreConfig)
from repro.serving import Engine, EngineConfig, GenRequest, SamplingParams


def _gen(rid, prompt, max_new, priority=0):
    return GenRequest(prompt_tokens=tuple(prompt), rid=rid, priority=priority,
                      sampling=SamplingParams(max_new_tokens=max_new))


def _drain(core, max_steps=500):
    for _ in range(max_steps):
        plan = core.begin_step()
        core.finish_step()
        if not core.running and not core.pending:
            return plan
    raise AssertionError("core did not drain")


def test_backend_protocol():
    assert isinstance(CostModelBackend(), ReplicaBackend)


# --------------------------------------------------- oversized rejection

def test_oversized_rejected_not_hol_deadlock_core():
    """A request whose KV need exceeds the replica budget must be rejected
    with an error, not sit at the head of pending starving everyone."""
    core = ReplicaCore(ReplicaCoreConfig(page_size=1, n_pages=32,
                                         record_decisions=True),
                       CostModelBackend())
    core.submit(_gen(0, range(30), 10))        # needs 40 > 32
    core.submit(_gen(1, range(100, 110), 4))   # must still be served
    plan = core.begin_step()
    assert [s.req.rid for s in plan.rejected] == [0]
    assert plan.rejected[0].error and "budget" in plan.rejected[0].error
    assert [s.req.rid for s in plan.admitted] == [1]
    _drain(core)
    assert core.completions == 1 and core.rejections == 1
    assert ("reject", 0) in core.decisions


def test_oversized_rejected_sim_host():
    sim = Sim()
    r = ReplicaSim(sim, "r0", "us", ReplicaConfig(kv_budget=32))
    done = []
    big = Request(rid=0, user_id="u", session_key="u", region="us",
                  prompt_tokens=tuple(range(30)), output_len=10,
                  output_tokens=tuple(range(10)), done_cb=done.append)
    ok = Request(rid=1, user_id="u", session_key="u", region="us",
                 prompt_tokens=tuple(range(100, 110)), output_len=4,
                 output_tokens=tuple(range(4)), done_cb=done.append)
    r.enqueue(big)
    r.enqueue(ok)
    sim.run(until=60)
    assert len(done) == 2
    by_rid = {q.rid: q for q in done}
    assert by_rid[0].error is not None and by_rid[0].finished is not None
    assert by_rid[1].error is None and by_rid[1].finished is not None
    assert r.completions == 1


def test_reject_callback_can_resubmit_sim_host():
    """A done_cb that synchronously re-enqueues on rejection must not wedge
    the replica (the _step early-return re-checks pending)."""
    sim = Sim()
    r = ReplicaSim(sim, "r0", "us", ReplicaConfig(kv_budget=32))
    done = []

    def retry_smaller(q):
        if q.error is not None and not done:
            r.enqueue(Request(rid=q.rid + 1, user_id="u", session_key="u",
                              region="us", prompt_tokens=q.prompt_tokens[:10],
                              output_len=4, output_tokens=tuple(range(4)),
                              done_cb=done.append))

    r.enqueue(Request(rid=0, user_id="u", session_key="u", region="us",
                      prompt_tokens=tuple(range(30)), output_len=10,
                      output_tokens=tuple(range(10)), done_cb=retry_smaller))
    sim.run(until=60)
    assert len(done) == 1 and done[0].finished is not None
    assert r.completions == 1


def test_oversized_rejected_engine(qwen_reduced, qwen_model_params):
    _, params = qwen_model_params
    eng = Engine(qwen_reduced, params,
                 EngineConfig(page_size=8, n_pages=8, max_batch=4,
                              max_seq_len=512, prefill_pad=16))
    rng = np.random.default_rng(0)
    big = _gen(1000, rng.integers(1, qwen_reduced.vocab, size=40).tolist(), 32)
    ok = _gen(1001, rng.integers(1, qwen_reduced.vocab, size=12).tolist(), 4)
    res = eng.generate([big, ok])
    assert res[0].finish_reason.value == "abort" and res[0].error
    assert res[1].finish_reason.value == "length" and res[1].error is None
    assert eng.completions == 1


# --------------------------------------------- fully-cached prompt rule

def test_fully_cached_prompt_reprefills_last_page(qwen_reduced,
                                                  qwen_model_params):
    """When the radix covers the WHOLE prompt, the final page is dropped so
    prefill still produces next-token logits."""
    _, params = qwen_model_params
    eng = Engine(qwen_reduced, params,
                 EngineConfig(page_size=8, n_pages=64, max_batch=4,
                              max_seq_len=256, prefill_pad=16))
    rng = np.random.default_rng(1)
    p = tuple(rng.integers(1, qwen_reduced.vocab, size=16).tolist())
    r1 = eng.generate([_gen(2000, p, 8)])[0]
    # turn 1 claimed exactly floor((16+8-1)/8)=2 pages == the prompt
    assert eng.radix.cached_pages == 2
    r2 = eng.generate([_gen(2001, p, 8)])[0]
    assert r2.cached_tokens == 8            # 16 matched, last page re-prefilled
    assert r2.output_tokens == r1.output_tokens   # greedy => same continuation


# --------------------------------------------- eviction under pressure

def test_eviction_under_pressure_core():
    core = ReplicaCore(ReplicaCoreConfig(page_size=1, n_pages=60,
                                         record_decisions=True),
                       CostModelBackend())
    core.submit(_gen(0, range(100, 130), 10))
    _drain(core)
    assert core.radix.cached_pages == 39          # 30 + 10 - last token
    core.submit(_gen(1, range(200, 230), 10))     # disjoint: needs 40 of 21 free
    _drain(core)
    evicted = [e for e in core.decisions if e[0] == "evict"]
    assert len(evicted) >= 19
    assert core.completions == 2
    # allocator hygiene: everything free or held once by the radix
    assert core.alloc.free_pages + core.radix.cached_pages == 60


def test_blocked_head_not_rematched_every_step():
    """A capacity-blocked head must not re-run the radix match (restamping
    its prefix MRU, O(prompt) work) on iterations where nothing changed."""
    core = ReplicaCore(ReplicaCoreConfig(page_size=1, n_pages=50),
                       CostModelBackend())
    calls = {"n": 0}
    real_match = core.radix.match

    def counting_match(tokens):
        calls["n"] += 1
        return real_match(tokens)

    core.radix.match = counting_match
    core.submit(_gen(0, range(20), 20))           # 40 of 50 pages
    core.begin_step()
    core.finish_step()
    core.submit(_gen(1, range(200, 225), 10))     # 35 pages: blocked
    calls["n"] = 0
    for _ in range(5):                            # rid 0 still running
        core.begin_step()
        core.finish_step()
    assert calls["n"] == 1                        # matched once, then memoized
    _drain(core)                                  # unblocks once rid 0 frees
    assert core.completions == 2


# --------------------------------------------- preemption -> resume

def test_priority_preemption_resume_core():
    core = ReplicaCore(ReplicaCoreConfig(page_size=1, n_pages=50,
                                         preemption=True,
                                         record_decisions=True),
                       CostModelBackend())
    low = _gen(10, range(20), 20)                 # 40 pages
    core.submit(low)
    core.begin_step()
    core.finish_step()
    assert [s.req.rid for s in core.running] == [10]
    high = _gen(11, range(300, 320), 5, priority=1)   # 25 pages > 10 free
    core.submit(high)
    plan = core.begin_step()
    assert ("preempt", 10) in core.decisions
    assert [s.req.rid for s in plan.admitted] == [11]
    core.finish_step()
    _drain(core)
    assert core.completions == 2 and core.preemptions == 1
    seqs = {e[1] for e in core.decisions if e[0] == "admit"}
    assert seqs == {10, 11}                       # low re-admitted after high
    # resume recompute is not a new prompt: stats count each prompt once
    assert core.total_prefill_tokens == 20 + 20
    assert low.cached_tokens == 0                 # first-admission value kept


def test_preemption_never_targets_finished_seq():
    """A sequence that completed at prefill (still in `running` until
    finish_step) must not be preempted — re-admission would sample a token
    beyond its max_new budget."""
    core = ReplicaCore(ReplicaCoreConfig(page_size=1, n_pages=30,
                                         preemption=True,
                                         record_decisions=True),
                       CostModelBackend())
    core.submit(_gen(0, range(20), 1))                # done at prefill
    core.submit(_gen(1, range(300, 315), 5, priority=1))
    core.begin_step()
    finished = core.finish_step()
    assert [s.req.rid for s in finished] == [0]
    assert len(finished[0].out) == 1                  # budget respected
    _drain(core)
    assert core.preemptions == 0
    assert not any(e[0] == "preempt" for e in core.decisions)
    done0 = [e for e in core.decisions if e[0] == "admit" and e[1] == 0]
    assert len(done0) == 1                            # admitted exactly once
    assert core.completions == 2


def test_preemption_resume_engine_output_unchanged(qwen_reduced,
                                                   qwen_model_params):
    """Preempt-and-recompute must not change a greedy request's output."""
    _, params = qwen_model_params
    ecfg = EngineConfig(page_size=8, n_pages=8, max_batch=4, max_seq_len=256,
                        prefill_pad=16, preemption=True)
    rng = np.random.default_rng(2)
    p_low = tuple(rng.integers(1, qwen_reduced.vocab, size=16).tolist())
    p_high = tuple(rng.integers(1, qwen_reduced.vocab, size=16).tolist())

    ref = Engine(qwen_reduced, params, ecfg).generate([_gen(3000, p_low, 16)])[0]

    eng = Engine(qwen_reduced, params, ecfg)
    eng.submit(_gen(3001, p_low, 16))             # 4 pages of 7
    eng.step()
    assert len(eng.running) == 1
    eng.submit(_gen(3002, p_high, 16, priority=1))  # 4 pages > 3 free
    eng.run_until_idle()
    assert eng.core.preemptions == 1
    res = eng.results[3001]
    assert res.output_tokens == ref.output_tokens
    assert eng.results[3002].finish_reason.value == "length"


# --------------------------------------------- chunked prefill

def test_chunked_prefill_matches_unchunked(qwen_reduced, qwen_model_params):
    _, params = qwen_model_params
    base = dict(page_size=8, n_pages=64, max_batch=4, max_seq_len=256,
                prefill_pad=16)
    rng = np.random.default_rng(3)
    prompts = [tuple(rng.integers(1, qwen_reduced.vocab, size=n).tolist())
               for n in (26, 9, 17)]
    out_ref = Engine(qwen_reduced, params, EngineConfig(**base)).generate(
        [_gen(4000 + i, p, 6) for i, p in enumerate(prompts)])
    out_chk = Engine(qwen_reduced, params, EngineConfig(
        **base, prefill_chunk=8)).generate(
        [_gen(4100 + i, p, 6) for i, p in enumerate(prompts)])
    for a, b in zip(out_ref, out_chk):
        assert a.output_tokens == b.output_tokens


def test_chunk_boundaries_page_aligned():
    calls = []

    class SpyBackend(CostModelBackend):
        def prefill(self, seq, start, end, sample):
            calls.append((start, end, sample))
            return super().prefill(seq, start, end, sample)

    core = ReplicaCore(ReplicaCoreConfig(page_size=4, n_pages=32,
                                         prefill_chunk=8), SpyBackend())
    core.submit(_gen(0, range(18), 4))
    core.begin_step()
    assert calls == [(0, 8, False), (8, 16, False), (16, 18, True)]
    assert all(s % 4 == 0 for s, _, _ in calls)
    _drain(core)


# --------------------------------------------- per-instance LRU clock

def test_radix_clock_is_per_instance():
    """Eviction stamps must not depend on unrelated caches created earlier
    in the same process (the old module-global clock did)."""
    def build_and_evict():
        a = BlockAllocator(16)
        r = PagedRadix(a, page_size=4)
        p = a.alloc(2)
        r.insert(tuple(range(4)), [p[0]])
        r.insert(tuple(range(100, 104)), [p[1]])
        a.free_all(p)
        r.match(tuple(range(4)))          # touch the first -> second is LRU
        freed: list = []
        r.evict(1, freed)
        return freed, [n.stamp for n in r._leaves.values()]

    f1, stamps1 = build_and_evict()
    # churn an unrelated cache in between
    noisy = PagedRadix(BlockAllocator(8), page_size=1)
    q = noisy.alloc.alloc(4)
    noisy.insert(tuple(range(4)), q)
    f2, stamps2 = build_and_evict()
    assert f1 == f2
    assert stamps1 == stamps2             # stamp VALUES reproducible too
