"""Checkpoint layer: atomicity, retention, dtype fidelity, error paths."""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.training.checkpoint import (latest_step, list_steps,
                                       restore_checkpoint, save_checkpoint)


def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip_with_bf16(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), s, step=7)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
    r, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    assert r["params"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    np.testing.assert_array_equal(
        np.asarray(r["params"]["b"], np.float32),
        np.asarray(s["params"]["b"], np.float32))


def test_retention_prunes_old(tmp_path):
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), _state(), step, keep=2)
    assert list_steps(str(tmp_path)) == [4, 5]
    assert latest_step(str(tmp_path)) == 5


def test_no_tmp_dir_left_behind(tmp_path):
    save_checkpoint(str(tmp_path), _state(), 1)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), _state())


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), _state(), 1)
    bad = _state()
    bad["params"]["w"] = jnp.zeros((9, 9), jnp.float32)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_restore_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), _state(), 1)
    extra = _state()
    extra["params"]["new"] = jnp.zeros((2,), jnp.float32)
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), extra)


def test_crash_mid_save_preserves_previous(tmp_path, monkeypatch):
    """A failed save must leave the previous checkpoint intact."""
    save_checkpoint(str(tmp_path), _state(), 1)
    import repro.training.checkpoint as ck

    def boom(*a, **k):
        raise RuntimeError("disk died")
    monkeypatch.setattr(ck.np, "save", boom)
    with pytest.raises(RuntimeError):
        save_checkpoint(str(tmp_path), _state(), 2)
    monkeypatch.undo()
    assert latest_step(str(tmp_path)) == 1
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        _state())
    r, step = restore_checkpoint(str(tmp_path), like)
    assert step == 1
