"""DEPRECATED shim: `BlockAllocator` moved to `repro.replica.blocks` (the
backend-agnostic replica scheduler core); this path remains for existing
imports."""
from __future__ import annotations

import warnings

from repro.replica.blocks import BlockAllocator  # noqa: F401

warnings.warn("repro.serving.blocks is deprecated; import BlockAllocator "
              "from repro.replica.blocks instead", DeprecationWarning,
              stacklevel=2)

__all__ = ["BlockAllocator"]
