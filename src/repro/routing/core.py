"""Transport-agnostic routing brain (paper §3.2-§3.3, Alg. 1).

One `RoutingCore` per load balancer holds everything that makes SkyLB's
decisions: heartbeat snapshots of local replicas and peer LBs, pushing-mode
eligibility, the two-layer dispatch loop, snapshot-optimism accounting
between probes (`max_inflight_per_probe`), cross-region forwarding, and
receiver-initiated work stealing.  What it deliberately does NOT know is how
requests move or time passes — that lives behind the `Transport` protocol,
so the discrete-event simulator (`repro.core.simulator.LoadBalancerSim`) and
the real-engine router (`repro.serving.router.InProcessRouter`) run the
byte-identical decision procedure over different substrates.

Hosts drive the core through four entry points:

  on_request(req)        a request arrives at this LB (local client, a
                         peer's forward, or a stolen request)
  refresh_local(views)   a heartbeat probe of local replicas completed
  refresh_remote(views)  a WAN heartbeat of peer LBs completed
  maybe_steal()          after a local probe, consider pulling peer work

Requests only need `rid` plus a writable `forwarded` attribute slot (both
the simulator's `Request` and the engine's `GenRequest` qualify); policies
additionally read `session_key` / `prompt_tokens`.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Protocol, Sequence, runtime_checkable

from repro.routing.hedging import HedgeParams, should_hedge
from repro.routing.kvtransfer import (PULL, PUSH, RECOMPUTE, KVTransferParams,
                                      decide)
from repro.routing.policies import SP_P, Policy, TargetView, eligible
from repro.serving.request import slo_priority
from repro.tenancy.admission import (DEFAULT_ADMISSION, AdmissionParams,
                                     should_shed)
from repro.tenancy.discipline import tenant_of, tenant_weight_of
from repro.tenancy.ledger import TenantLedger


@runtime_checkable
class Transport(Protocol):
    """How a RoutingCore's decisions reach the world.

    Implementations own latency (WAN one-way delays, tick queues, zero),
    liveness, and the clock; the core owns the decisions.
    """

    def now(self) -> float:
        """Current time (simulated seconds, ticks — any monotonic unit)."""
        ...

    def target_alive(self, target_id: str) -> bool:
        """Is this local replica/engine currently usable?"""
        ...

    def peer_alive(self, peer_id: str) -> bool:
        """Is this peer LB currently usable?"""
        ...

    def deliver(self, req, target_id: str) -> None:
        """Hand `req` to a local replica/engine (transport adds latency)."""
        ...

    def forward(self, req, peer_id: str) -> None:
        """Hand `req` to a peer LB (transport adds WAN latency)."""
        ...

    def steal_request(self, peer_id: str, n: int) -> None:
        """Ask a peer LB to release up to n queued requests to us."""
        ...

    def pull_pages(self, req, peer_id: str, target_id: str,
                   prefix_len: int, pull_tokens: int) -> None:
        """Fetch the KV for `req`'s first `prefix_len` prompt tokens from
        `peer_id`'s region (only ~pull_tokens of them actually cross the
        WAN — the rest are already local) and deliver `req` to local
        `target_id` once the pages land (transport adds the WAN round trip
        + bytes/bandwidth latency)."""
        ...


@dataclasses.dataclass
class RoutingConfig:
    pushing: str = SP_P             # BP | SP-O | SP-P
    spo_limit: int = 24
    tau: int = 4                    # remote-forward queue buffer
    probe_interval: float = 0.05
    # cross-region heartbeats ride the WAN: they are refreshed slower than
    # local probes (>= one RTT; the paper's regions are 140-200 ms apart)
    remote_probe_interval: float = 0.2
    cross_region: bool = True       # two-layer forwarding enabled
    # SP-P optimism bound: between heartbeats the LB may send at most this
    # many requests to a replica last seen with an empty pending queue.
    # Alg. 1 is unbounded between probes (availability only refreshes at
    # heartbeats), so the default is high — a backstop, not a throttle;
    # lowering it trades burst absorption for stricter queue control.
    max_inflight_per_probe: int = 64
    # BEYOND-PAPER work stealing (paper §6 cites stealing > shedding for
    # CPU loads): an idle LB PULLS from the most-backlogged peer instead of
    # waiting for that peer to push. Complements SP-P forwarding, which is
    # sender-initiated (shedding-style).
    work_stealing: bool = False
    steal_threshold: int = 4        # only steal from queues deeper than this
    steal_batch: int = 2            # requests pulled per steal
    # BEYOND-PAPER cross-region KV-page transfer: on a strong remote prefix
    # hit, weigh pulling the KV pages over the WAN (serve locally) against
    # pushing the request (forward, the paper's only option) against plain
    # local recompute, via repro.routing.kvtransfer.decide. Needs prefix-
    # aware local AND remote policies (their tries estimate hit lengths).
    kv_transfer: bool = False
    kv_params: Optional[KVTransferParams] = None    # default params if None
    # BEYOND-PAPER hedged dispatch: duplicate a `latency`-class request to a
    # second region when the chosen replica's predicted TTFT blows the
    # request's budget (repro.routing.hedging). First token wins; the
    # transport reaps the loser through the exactly-once cancel path.
    hedging: bool = False
    hedge_params: Optional[HedgeParams] = None      # default params if None
    # Multi-tenant fairness (repro.tenancy): per-tenant service counters
    # folded into dispatch/steal scoring, carried in heartbeats so every LB
    # converges on the same view. A HEAVY tenant (counter > factor * mean)
    # loses its cache-affinity preference — it is routed least-load — and
    # its queued work is released to thieves first.
    fairness: bool = False
    fairness_factor: float = 2.0
    # SLO lanes: `slo_class`es with positive priority ("interactive",
    # "latency") enqueue in a fast-lane PREFIX of the queue (FCFS within a
    # lane); off by default — plain FCFS, byte-identical to pre-tenancy.
    slo_lanes: bool = False
    # Deadline-aware admission shedding at the LB: when the chosen
    # replica's snapshot-predicted TTFT already exceeds the head request's
    # deadline, resolve it as FinishReason.SHED instead of dispatching
    # (repro.tenancy.admission; transports without a `shed` method opt out).
    admission: bool = False
    admission_params: Optional[AdmissionParams] = None  # None = defaults
    # Record ("local"|"forward"|"steal"|"pull", rid, target) tuples for
    # parity tests / tracing. Off by default (unbounded list).
    record_decisions: bool = False


class RoutingCore:
    """The single implementation of SkyLB eligibility + two-layer dispatch."""

    def __init__(self, lb_id: str, policy: Policy,
                 remote_policy: Optional[Policy] = None,
                 cfg: Optional[RoutingConfig] = None,
                 transport: Optional[Transport] = None):
        if transport is None:
            raise ValueError("RoutingCore requires a Transport")
        self.id = lb_id
        self.policy = policy
        self.remote_policy = remote_policy
        self.cfg = cfg if cfg is not None else RoutingConfig()
        self.transport = transport
        self.queue: deque = deque()
        # probe snapshots (stale between probes — like real heartbeats)
        self._replica_snap: dict[str, TargetView] = {}
        self._lb_snap: dict[str, TargetView] = {}
        self._sent_since_probe: dict[str, int] = {}
        self.forwarded_out = 0
        self.peak_queue = 0
        # KV-transfer accounting (all zero with kv_transfer off)
        self.kv_decisions = {PULL: 0, PUSH: 0, RECOMPUTE: 0}
        self.pulled_tokens = 0
        self.hedges = 0
        # per-tenant EXPECTED service (prompt + output budget per dispatch),
        # max-merged with peers' heartbeat snapshots (repro.tenancy.ledger)
        self.tenants = TenantLedger()
        self.sheds = 0
        self.decisions: Optional[list[tuple]] = (
            [] if self.cfg.record_decisions else None)

    # ---- topology
    def target_added(self, view: TargetView) -> None:
        """A local replica joined (fresh view, routable before next probe)."""
        self.policy.on_target_added(view.id)
        self._replica_snap[view.id] = view

    def target_removed(self, target_id: str) -> None:
        self.policy.on_target_removed(target_id)
        self._replica_snap.pop(target_id, None)

    def peer_added(self, peer_id: str) -> None:
        if self.remote_policy is not None:
            self.remote_policy.on_target_added(peer_id)

    # ---- availability monitor (Alg.1 MonitorAvailability)
    def refresh_local(self, views: Sequence[TargetView]) -> None:
        """A local heartbeat completed: replace snapshots, reset the
        between-probe optimism budget, and drain what became routable."""
        self._sent_since_probe.clear()
        for v in views:
            self._replica_snap[v.id] = v
            if self.cfg.fairness:
                # replica-side VTC counters (tokens actually served) fold
                # into the router's expected-service ledger via max-merge
                self.tenants.merge(v.tenant_counters)
        self.try_dispatch()

    def refresh_remote(self, views: Sequence[TargetView]) -> None:
        """A WAN heartbeat of peer LBs completed."""
        for v in views:
            self._lb_snap[v.id] = v
            if self.cfg.fairness:
                self.tenants.merge(v.tenant_counters)
        self.try_dispatch()

    def tenant_snapshot(self) -> Optional[dict]:
        """This LB's ledger for heartbeat publication (None when fairness
        is off — keeps wire frames lean and old peers decodable)."""
        return self.tenants.snapshot() if self.cfg.fairness else None

    def n_avail_local(self) -> int:
        return sum(1 for v in self._replica_snap.values()
                   if v.available and self.transport.target_alive(v.id))

    # ---- request path (Alg.1 HandleRequest)
    def on_request(self, req) -> None:
        if (self.cfg.slo_lanes
                and slo_priority(getattr(req, "slo_class", "standard")) > 0):
            # fast lane: join behind other fast-class work but ahead of the
            # slow lane (the queue's invariant is fast-prefix-then-slow, so
            # the insertion point is the end of the fast prefix)
            pos = 0
            for q in self.queue:
                if slo_priority(getattr(q, "slo_class", "standard")) <= 0:
                    break
                pos += 1
            self.queue.insert(pos, req)
        else:
            self.queue.append(req)
        self.peak_queue = max(self.peak_queue, len(self.queue))
        self.try_dispatch()

    # ---- cancellation
    def cancel(self, rid):
        """Pull a still-queued request out of the FCFS queue. Returns the
        request (the host resolves it as cancelled) or None if it already
        left this LB — dispatched to a replica, forwarded, released to a
        thief, or on the WAN. For those, the host sets `req.cancelled` and
        the next host to see the request resolves it exactly once (there is
        ONE request object, so a cancel racing a steal can't double-fire)."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                if self.decisions is not None:
                    self.decisions.append(("cancel", rid, self.id))
                return req
        return None

    def _local_views(self) -> list[TargetView]:
        return [v for v in self._replica_snap.values()
                if self.transport.target_alive(v.id)]

    def try_dispatch(self) -> None:
        """Two-layer dispatch: drain the FCFS queue head while some local
        replica is eligible; else forward the head once across regions;
        else the head waits for capacity (later arrivals wait behind it)."""
        cfg = self.cfg
        while self.queue:
            req = self.queue[0]
            local_views = self._local_views()
            locals_ok = eligible(local_views, cfg.pushing,
                                 cfg.spo_limit, cfg.tau)
            if locals_ok:
                heavy = (cfg.fairness and self.tenants.is_heavy(
                    tenant_of(req), cfg.fairness_factor))
                if heavy:
                    # a heavy tenant's cache affinity stops overriding
                    # regional fairness: route least-load, not by prefix
                    tid = min(locals_ok,
                              key=lambda v: (v.outstanding, v.id)).id
                    if self.decisions is not None:
                        self.decisions.append(("fair", req.rid,
                                               tenant_of(req)))
                else:
                    tid = self.policy.select(req, locals_ok)
                if tid is None or not any(v.id == tid for v in locals_ok):
                    # a policy may answer from its own state (trie records,
                    # hashring) that still names a target removed between
                    # probes — never dispatch outside the eligible set
                    tid = locals_ok[0].id
                if cfg.admission and self._should_shed(req, tid):
                    self.queue.popleft()
                    self._shed(req)
                    continue
                # a heavy tenant also forfeits the KV-pull privilege — a
                # WAN page transfer is exactly the locality subsidy being
                # withdrawn
                act = None if heavy else self._kv_consult(req, locals_ok)
                if act is not None:
                    kind, peer, pull_spec = act
                    self.queue.popleft()
                    if kind == PULL:
                        self._send_pull(req, peer, tid, *pull_spec)
                    else:                           # PUSH on a remote hit
                        self.kv_decisions[PUSH] += 1
                        self._forward(req, peer)
                    continue
                self.queue.popleft()
                self._send_local(req, tid)
                self._maybe_hedge(req, tid)
                continue
            # one WAN hop normally — but an LB that owns ZERO live targets
            # (elastic scale-to-zero, region outage) can never serve the
            # head itself, so already-forwarded work may hop again rather
            # than head-of-line-block the queue forever
            reforward = bool(getattr(req, "forwarded", False))
            if (cfg.cross_region and self._lb_snap
                    and self.remote_policy is not None
                    and (not reforward or not local_views)):
                remotes_ok = eligible(list(self._lb_snap.values()),
                                      cfg.pushing, cfg.spo_limit, cfg.tau)
                remotes_ok = [v for v in remotes_ok
                              if self.transport.peer_alive(v.id)]
                if reforward:
                    # a re-forward must land where replicas EXIST (busy is
                    # fine — n_replicas, not the idle n_avail_replicas
                    # count), or two emptied regions could ping-pong it
                    # indefinitely under BP/SP-O eligibility
                    remotes_ok = [v for v in remotes_ok
                                  if v.n_replicas > 0]
                if remotes_ok:
                    lbid = self.remote_policy.select(req, remotes_ok)
                    if (lbid is not None
                            and not any(v.id == lbid for v in remotes_ok)):
                        lbid = remotes_ok[0].id     # same stale-state guard
                    if lbid is not None:
                        self.queue.popleft()
                        self._forward(req, lbid)
                        continue
            break   # head-of-line waits for capacity

    def _kv_consult(self, req, locals_ok) -> Optional[tuple]:
        """Bytes-vs-recompute consult for the head request. Returns
        (PULL, peer_id, pulled_tokens) or (PUSH, peer_id, 0) when moving KV
        or the request beats local recompute; None to serve locally as
        usual. Hit lengths come from the policies' PREFIX TRIES — the same
        state both hosts replicate deterministically — never from clocks or
        queue depths, so decisions are parity-safe."""
        cfg = self.cfg
        if not cfg.kv_transfer or getattr(req, "forwarded", False):
            return None
        ltree = getattr(self.policy, "tree", None)
        rtree = getattr(self.remote_policy, "tree", None)
        if ltree is None or rtree is None or not self._lb_snap:
            return None
        prompt = tuple(getattr(req, "prompt_tokens", ()) or ())
        if not prompt:
            return None
        local_hit, _ = ltree.match(prompt, [v.id for v in locals_ok])
        peers = [pid for pid, v in self._lb_snap.items()
                 if v.n_replicas > 0 and self.transport.peer_alive(pid)]
        remote_hit, peer = rtree.match(prompt, peers)
        if peer is None or remote_hit <= local_hit:
            return None
        params = cfg.kv_params if cfg.kv_params is not None \
            else KVTransferParams()
        choice, costs = decide(len(prompt), local_hit, remote_hit, params)
        if choice == PULL:
            return PULL, peer, (remote_hit, int(costs["pulled_tokens"]))
        if choice == PUSH:
            return PUSH, peer, None
        self.kv_decisions[RECOMPUTE] += 1
        return None

    def _should_shed(self, req, tid: str) -> bool:
        """Deadline-aware admission verdict for the head request against
        the chosen replica's snapshot (pure: queue depths + prompt length +
        deadline — parity-safe across hosts)."""
        snap = self._replica_snap.get(tid)
        if snap is None:
            return False
        params = (self.cfg.admission_params
                  if self.cfg.admission_params is not None
                  else DEFAULT_ADMISSION)
        return should_shed(len(getattr(req, "prompt_tokens", ()) or ()),
                           snap.pending, snap.outstanding,
                           getattr(req, "deadline_s", None), params)

    def _shed(self, req) -> None:
        """Resolve a shed request through the transport (FinishReason.SHED
        at the host). Transports without a `shed` method opt out — the
        request is dropped from the queue either way, so fixtures just see
        the decision record."""
        self.sheds += 1
        if self.decisions is not None:
            self.decisions.append(("shed", req.rid, self.id))
        shed_fn = getattr(self.transport, "shed", None)
        if shed_fn is not None:
            shed_fn(req)

    def _charge(self, req) -> None:
        """Charge the tenant the EXPECTED tokens of this dispatch (prompt +
        output budget). Coarser than the replica's exact VTC charge, but
        available at decision time and monotone — no refunds on cancel."""
        if not self.cfg.fairness:
            return
        sp = getattr(req, "sampling", None)
        budget = (sp.max_new_tokens if sp is not None
                  else getattr(req, "output_len", 0))
        prompt = getattr(req, "prompt_tokens", ()) or ()
        self.tenants.charge(tenant_of(req), len(prompt) + int(budget),
                            tenant_weight_of(req))

    def _send_pull(self, req, peer_id: str, tid: str, prefix_len: int,
                   pull_tokens: int) -> None:
        """Serve locally after pulling the prefix KV from `peer_id`'s
        region: the transport replays the remote pages into `tid`'s replica
        cache and delivers the request there after the WAN transfer."""
        self.policy.on_routed(req, tid)     # the prefix now lives HERE
        snap = self._replica_snap.get(tid)
        if snap:
            snap.pending += 1
            snap.outstanding += 1
            sent = self._sent_since_probe.get(tid, 0) + 1
            self._sent_since_probe[tid] = sent
            if sent >= self.cfg.max_inflight_per_probe:
                snap.available = False
        self.kv_decisions[PULL] += 1
        self.pulled_tokens += pull_tokens
        self._charge(req)
        if self.decisions is not None:
            self.decisions.append(("pull", req.rid, peer_id))
        self.transport.pull_pages(req, peer_id, tid, prefix_len, pull_tokens)

    def _send_local(self, req, rid: str) -> None:
        self.policy.on_routed(req, rid)
        # bump snapshot counts so least-load tie-breaks shift between probes;
        # availability refreshes at probes (Alg. 1), with optimistic sends
        # between heartbeats bounded by max_inflight_per_probe
        snap = self._replica_snap.get(rid)
        if snap:
            snap.pending += 1
            snap.outstanding += 1
            sent = self._sent_since_probe.get(rid, 0) + 1
            self._sent_since_probe[rid] = sent
            if sent >= self.cfg.max_inflight_per_probe:
                snap.available = False
        self._charge(req)
        if self.decisions is not None:
            self.decisions.append(("local", req.rid, rid))
        self.transport.deliver(req, rid)

    def _maybe_hedge(self, req, tid: str) -> None:
        """After a local send, consider duplicating a `latency`-class
        request to the healthiest remote region (pure snapshot rule in
        repro.routing.hedging). The transport owns the race: first token
        wins, the loser is cancelled exactly once. Transports without a
        `hedge` method (plain fixtures) silently opt out."""
        cfg = self.cfg
        if not cfg.hedging or not self._lb_snap:
            return
        hedge_fn = getattr(self.transport, "hedge", None)
        if hedge_fn is None:
            return
        snap = self._replica_snap.get(tid)
        if snap is None:
            return
        params = cfg.hedge_params if cfg.hedge_params is not None \
            else HedgeParams()
        if not should_hedge(req, snap, params):
            return
        # a hedge must land where replicas EXIST (busy is fine) — same
        # guard as re-forwarding, or it would bounce off an empty region
        peers = [v for v in self._lb_snap.values()
                 if v.n_replicas > 0 and self.transport.peer_alive(v.id)]
        if not peers:
            return
        peer = max(peers,
                   key=lambda v: (v.n_avail_replicas, -v.queue_len)).id
        self.hedges += 1
        if self.decisions is not None:
            self.decisions.append(("hedge", req.rid, peer))
        hedge_fn(req, peer)

    def _forward(self, req, lbid: str) -> None:
        req.forwarded = True
        self.forwarded_out += 1
        if self.remote_policy:
            self.remote_policy.on_routed(req, lbid)
        snap = self._lb_snap.get(lbid)
        if snap:
            snap.queue_len += 1
        if self.decisions is not None:
            self.decisions.append(("forward", req.rid, lbid))
        self.transport.forward(req, lbid)

    # ---- work stealing (beyond-paper; receiver-initiated rebalancing)
    def maybe_steal(self) -> None:
        """Idle here + deep queue there => pull work (one steal per probe)."""
        if not self.cfg.work_stealing:
            return
        if self.queue or self.n_avail_local() == 0 or not self._lb_snap:
            return
        # dead peers advertise sentinel (10**9) queue lengths; skip them or
        # one downed LB would monopolize (and void) every steal attempt
        victim = max((v for v in self._lb_snap.values()
                      if self.transport.peer_alive(v.id)),
                     key=lambda v: v.queue_len, default=None)
        if victim is None or victim.queue_len <= self.cfg.steal_threshold:
            return
        self.transport.steal_request(victim.id, self.cfg.steal_batch)

    def release_for_steal(self, n: int,
                          thief_id: Optional[str] = None) -> list:
        """A peer with idle capacity asks for up to n TAIL requests (the
        head keeps local FCFS fairness). Never re-steal forwarded work.
        Returns the released requests; the host delivers them.

        With fairness on, HEAVY tenants' queued work leaves first (tail-
        ward, head excluded): moving their backlog to the idle region both
        balances load and un-crowds the tenants they were starving."""
        out = []
        if self.cfg.fairness and len(self.queue) > self.cfg.steal_threshold:
            picks = []      # descending indices -> deletions stay valid
            for i in range(len(self.queue) - 1, 0, -1):
                if (len(picks) >= n or len(self.queue) - len(picks)
                        <= self.cfg.steal_threshold):
                    break
                req = self.queue[i]
                if getattr(req, "forwarded", False):
                    continue
                if self.tenants.is_heavy(tenant_of(req),
                                         self.cfg.fairness_factor):
                    picks.append(i)
            for i in picks:
                req = self.queue[i]
                del self.queue[i]
                req.forwarded = True
                self.forwarded_out += 1
                if self.decisions is not None:
                    self.decisions.append(("steal", req.rid, thief_id))
                out.append(req)
            n -= len(out)
        for _ in range(n):
            if len(self.queue) <= self.cfg.steal_threshold:
                break
            req = self.queue.pop()          # tail
            if getattr(req, "forwarded", False):
                self.queue.append(req)      # don't bounce; put it back
                break
            req.forwarded = True            # one WAN hop max, like _forward
            self.forwarded_out += 1
            if self.decisions is not None:
                self.decisions.append(("steal", req.rid, thief_id))
            out.append(req)
        return out
