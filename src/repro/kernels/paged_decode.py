"""Paged decode attention as a Pallas TPU kernel — the KV-cache hot spot
whose locality SkyLB's prefix-aware routing protects.

TPU adaptation of GPU paged attention (DESIGN §3): instead of per-warp
gathers, the grid walks (batch, kv-page) with the page axis innermost and
sequential; the *scalar-prefetched* block table drives the BlockSpec index
map, so each step DMAs exactly one (page_size, K, hd) KV tile HBM->VMEM.
An online softmax over all query heads for that sequence accumulates in
VMEM scratch.

The grid is RAGGED per sequence: the scalar-prefetched `seq_lens` clamp the
BlockSpec index map to the sequence's last live page, so grid steps past a
sequence's real page count re-reference the tile already resident in VMEM
(Pallas elides the DMA when consecutive block indices coincide) and run no
compute; the output is written at the sequence's last live page, not at the
grid edge. Consequence for callers: block-table entries at or beyond a
sequence's page count `ceil(seq_len / page)` are NEVER dereferenced and may
hold arbitrary int32 garbage (the jnp oracle `ref.paged_decode_ref`
implements the same contract). `seq_lens` must be >= 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38

# jax < 0.5 spells it TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _last_page(seq_len, page: int):
    """Index of the last live page for a sequence (seq_len >= 1)."""
    return jnp.maximum(seq_len - 1, 0) // page


def _kernel(bt_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, npg: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    seq_len = lens_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * page < seq_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)                     # (H, hd)
        k = k_ref[0].astype(jnp.float32)                     # (page, K, hd)
        v = v_ref[0].astype(jnp.float32)
        H, hd = q.shape
        K = k.shape[1]
        G = H // K
        qg = q.reshape(K, G, hd)
        # scores: (K, G, page)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # (K, G, page)
        s = s * scale
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (K, G, page), 2)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        s = s.reshape(H, page)
        m_prev = m_ref[...]                                  # (H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                               # (H, page)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pg = p.reshape(K, G, page)
        pv = jax.lax.dot_general(
            pg, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # (K, G, hd)
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(H, hd)
        m_ref[...] = m_new

    # ragged early-out: the result is complete once this sequence's last
    # live page has been accumulated; later grid steps are no-ops
    @pl.when(j == _last_page(seq_len, page))
    def _out():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode(q, k_pages, v_pages, block_table, seq_lens, *,
                 interpret: bool = False) -> jax.Array:
    """q: (B,H,hd); k_pages/v_pages: (P,page,K,hd); block_table: (B,NPG)
    int32 — entries beyond each sequence's live page count are never read
    and may be garbage; seq_lens: (B,), >= 1. Returns (B,H,hd)."""
    B, H, hd = q.shape
    Ptot, page, K, _ = k_pages.shape
    npg = block_table.shape[1]
    assert H % K == 0

    def _kv_index(b, j, bt, ln):
        # clamp to the last live page: steps past the ragged edge re-issue
        # the previous index, so no fresh DMA lands and garbage table
        # entries are never dereferenced
        return (bt[b, jnp.minimum(j, _last_page(ln[b], page))], 0, 0, 0)

    kernel = functools.partial(_kernel, page=page, npg=npg, scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # block_table, seq_lens
        grid=(B, npg),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, K, hd), _kv_index),
            pl.BlockSpec((1, page, K, hd), _kv_index),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),         # running max
            pltpu.VMEM((H, 1), jnp.float32),         # running denom
            pltpu.VMEM((H, hd), jnp.float32),        # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, seq_lens, q, k_pages, v_pages)
