"""DEPRECATED shim — `repro.core.prefixtree` moved to
`repro.routing.prefixtree`. Import from `repro.routing` instead.
"""
from repro.routing.prefixtree import PrefixTree  # noqa: F401

__all__ = ["PrefixTree"]
