"""Consistent hashing (ring hash with virtual nodes) — SkyLB-CH §3.2.

Two SkyLB extensions over classic ring hash (Karger et al. / Chord):
  1. applied at BOTH layers (LB->LB and LB->replica);
  2. virtual nodes whose target is unavailable are SKIPPED, continuing
     clockwise (Listing 1, line 26).
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Hashable, Iterable, Optional


def _hash(key: str) -> int:
    return int.from_bytes(hashlib.md5(key.encode()).digest()[:8], "big")


class HashRing:
    def __init__(self, targets: Iterable[Hashable] = (), vnodes: int = 100):
        self.vnodes = vnodes
        self._ring: list[tuple[int, Hashable]] = []
        self._targets: set[Hashable] = set()
        for t in targets:
            self.add(t)

    def add(self, target: Hashable) -> None:
        if target in self._targets:
            return
        self._targets.add(target)
        for i in range(self.vnodes):
            bisect.insort(self._ring, (_hash(f"{target}#{i}"), target))

    def remove(self, target: Hashable) -> None:
        if target not in self._targets:
            return
        self._targets.discard(target)
        self._ring = [(h, t) for h, t in self._ring if t != target]

    @property
    def targets(self) -> set:
        return set(self._targets)

    def __len__(self) -> int:
        return len(self._targets)

    def lookup(self, key: str,
               available: Optional[set] = None) -> Optional[Hashable]:
        """First clockwise virtual node whose target is available."""
        if not self._ring:
            return None
        avail = self._targets if available is None else (self._targets & set(available))
        if not avail:
            return None
        h = _hash(key)
        idx = bisect.bisect_right(self._ring, (h, "￿"))
        n = len(self._ring)
        for off in range(n):
            _, target = self._ring[(idx + off) % n]
            if target in avail:
                return target
        return None
