"""Request/response types for the serving engine (OpenAI-completions-ish,
token-level: the LB layer and the engine both speak token ids)."""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import Optional

_rid = itertools.count()


class FinishReason(str, enum.Enum):
    LENGTH = "length"
    STOP = "stop"
    ABORT = "abort"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    max_new_tokens: int = 64
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => disabled
    stop_token: Optional[int] = None  # eos
    seed: int = 0


@dataclasses.dataclass
class GenRequest:
    prompt_tokens: tuple
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    rid: int = dataclasses.field(default_factory=lambda: next(_rid))
    user_id: str = ""
    session_key: str = ""
    priority: int = 0                 # higher may preempt lower (replica core)
    arrival_s: float = dataclasses.field(default_factory=time.monotonic)
    # filled by the engine:
    cached_tokens: int = 0
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


@dataclasses.dataclass
class GenResult:
    rid: int
    output_tokens: tuple
    finish_reason: FinishReason
    cached_tokens: int
    prompt_len: int
    ttft_s: Optional[float] = None
    e2e_s: Optional[float] = None
    error: Optional[str] = None       # set on ABORT (oversized rejection)
