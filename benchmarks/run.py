"""Benchmark harness: one module per paper figure + the kernel sweep.
Runs everything, prints per-figure results, writes artifacts/bench/*.json.

  PYTHONPATH=src python -m benchmarks.run [--only fig9] [--smoke]

--smoke bounds the simulated horizons so the whole sweep finishes in about
a minute — enough signal to catch routing-throughput regressions in CI
(scripts/ci.sh) without the full-length figures.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--smoke", action="store_true",
                    help="bounded sim horizons (fast CI regression check)")
    args = ap.parse_args()

    from benchmarks import (beyond_steal, fig3_aggregation, fig5_prefix,
                            fig6_hitrate, fig8_macro, fig9_pushing,
                            fig10_diurnal, kernels_bench)
    suites = {
        "fig3": fig3_aggregation.main,
        "fig5": fig5_prefix.main,
        "fig6": fig6_hitrate.main,
        "fig8": fig8_macro.main,
        "fig9": fig9_pushing.main,
        "fig10": fig10_diurnal.main,
        "kernels": kernels_bench.main,
        "steal": beyond_steal.main,
    }
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"===== {name} =====", flush=True)
        try:
            result = fn(smoke=args.smoke)
            with open(os.path.join(args.out, f"{name}.json"), "w") as f:
                json.dump(result, f, indent=1, default=str)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            print(f"[{name}] FAILED: {e}")
            failures += 1
        print(f"[{name}] {time.time() - t0:.1f}s", flush=True)
    print(f"benchmarks done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
