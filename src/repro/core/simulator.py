"""Discrete-event multi-region serving simulator.

Models: WAN RTTs between regions, per-replica continuous batching with a KV
token budget + radix prefix cache (TTFT = queueing + uncached prefill +
iteration), regional LBs with FCFS queues / heartbeat probes / two-layer
forwarding, a fault-tolerant controller (LB failover per paper §4.2),
stragglers and elastic scale-out.

The replica scheduler itself lives in the backend-agnostic
`repro.replica.ReplicaCore` — shared verbatim with the real JAX paged
engine — here driven with an analytic `CostModelBackend` at page_size=1
(pages == tokens). `ReplicaSim` is only the Sim-event host: it schedules
one event per continuous-batching iteration and puts the iteration's
analytic latency between the core's admission and decode phases.

Timing constants are calibrated to the paper's setup (Llama-3.1-8B on one
L4 via SGLang): ~1.7k tok/s prefill, ~30 tok/s/stream decode, KV budget
~32k tokens.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import warnings
from collections import deque
from typing import Callable, Optional

from repro.replica import CostModelBackend, ReplicaCore, ReplicaCoreConfig
from repro.routing.core import RoutingConfig, RoutingCore
from repro.routing.failover import FailoverTracker
from repro.routing.policies import BP, SP_O, SP_P, Policy, TargetView  # noqa: F401 — BP/SP_O/SP_P re-exported for callers


# ------------------------------------------------------------------ engine

class Sim:
    def __init__(self):
        self.now = 0.0
        self._heap: list = []
        self._seq = itertools.count()

    def at(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def after(self, dt: float, fn: Callable) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: float = float("inf"), max_events: int = 50_000_000):
        n = 0
        while self._heap and n < max_events:
            if self._heap[0][0] > until:     # peek — keep future events
                break
            t, _, fn = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            fn()
            n += 1
        return n


# ------------------------------------------------------------------ request

@dataclasses.dataclass
class Request:
    rid: int
    user_id: str
    session_key: str
    region: str
    prompt_tokens: tuple
    output_len: int
    output_tokens: tuple = ()       # deterministic completion (for reuse)
    priority: int = 0               # DEPRECATED when explicit: use slo_class
    tenant_weight: float = 1.0      # weighted fairness (repro.tenancy)
    arrival: float = 0.0            # at first LB
    issued: float = 0.0             # at client
    ttft: Optional[float] = None    # absolute time of first token
    finished: Optional[float] = None
    done_cb: Optional[Callable] = None
    cached_tokens: int = 0
    replica: Optional[str] = None
    forwarded: bool = False
    origin_lb: Optional[str] = None
    error: Optional[str] = None     # set when the replica rejects (oversized)
    # ---- lifecycle (unified front API; see repro.frontend) ----
    deadline_s: Optional[float] = None   # relative to `issued`
    slo_class: str = "standard"
    # a cancel that raced the request onto the WAN travels as this flag
    # ("cancelled" | "deadline"); the next host to see it resolves it once
    cancelled: Optional[str] = None
    # terminal disposition when not a plain completion
    finish_reason: Optional[str] = None
    # host -> frontend notifications (set by ServingSystem.submit)
    admit_cb: Optional[Callable] = None  # (req, t)
    token_cb: Optional[Callable] = None  # (req, token, index, t)

    def __post_init__(self):
        # The sim's integer `priority` used to be a second, parallel
        # priority notion next to `slo_class`. An EXPLICIT priority with
        # the default class now maps onto the matching SLO lane (and warns)
        # so there is one notion; requests that set both (the frontend
        # SimHost does, consistently) pass through untouched, and the
        # priority value itself is preserved, so replica-core scheduling
        # is identical either way.
        if self.priority != 0 and self.slo_class == "standard":
            warnings.warn(
                "Request.priority with a default slo_class is deprecated; "
                "set slo_class ('batch'|'interactive'|'latency') instead — "
                "mapping the priority onto the matching SLO lane",
                DeprecationWarning, stacklevel=3)
            if self.priority >= 2:
                self.slo_class = "latency"
            elif self.priority == 1:
                self.slo_class = "interactive"
            else:                   # priority < 0 yields to standard
                self.slo_class = "batch"


def resolve_cancelled(req: Request, now: float,
                      reason: Optional[str] = None) -> None:
    """Terminal resolution of a cancelled/deadline-aborted request — the
    ONE implementation every sim-side site uses (LB queue pull, replica
    reap, WAN-arrival of a travelling cancel flag), so 'resolves exactly
    once' bookkeeping can never diverge per location. Callers guard on
    `req.finished is None`."""
    req.finish_reason = reason or req.cancelled or "cancelled"
    req.finished = now
    if req.done_cb:
        req.done_cb(req)


# ------------------------------------------------------------------ replica

@dataclasses.dataclass
class ReplicaConfig:
    kv_budget: int = 32768          # tokens resident (running + cache)
    prefill_tps: float = 1700.0
    decode_base: float = 0.03       # s per iteration
    decode_per_seq: float = 0.0008  # s per running sequence
    speed_factor: float = 1.0       # >1 = straggler
    max_batch: int = 0              # max concurrent sequences; 0 = unbounded
    max_seq_len: int = 0            # prompt+output token cap; 0 = unbounded
    prefill_chunk: int = 0          # tokens per prefill chunk; 0 = unchunked
    preemption: bool = False        # priority preemption (recompute on resume)
    host_kv_budget: int = 0         # host-memory KV tier tokens; 0 = tier off
    kv_page_bytes: float = 131072.0  # bytes per KV page (page_size=1: token)
    host_copy_gbps: float = 20.0    # PCIe-class host<->device bandwidth
    # Speculative decoding (draft-k/verify-1), mirrored analytically by
    # CostModelBackend.decode_many; spec_k = 0 keeps the plain decode path.
    spec_k: int = 0                 # drafted tokens per decode iteration
    spec_accept_rate: float = 1.0   # per-draft acceptance probability
    spec_draft_cost: float = 0.15   # drafter fwd cost as fraction of target
    # Multi-tenant fairness + admission control (repro.tenancy); "fcfs"
    # keeps replica decision streams byte-identical to pre-tenancy.
    discipline: str = "fcfs"        # "fcfs" | "vtc" | "wvtc"
    cache_discount: float = 0.25    # VTC charge rate for cache-hit tokens
    shed_deadline: bool = False     # deadline-aware admission shedding


class ReplicaSim:
    """Thin Sim-event host around the shared `repro.replica.ReplicaCore`
    (CostModelBackend, page_size=1 so pages == tokens): one event per
    continuous-batching iteration, with the analytic iteration latency
    between the core's admission (`begin_step`) and decode (`finish_step`)
    phases. All admission / KV / radix / rejection / preemption decisions
    live in the core — shared with the real JAX `Engine`."""

    def __init__(self, sim: Sim, rid: str, region: str,
                 cfg: ReplicaConfig = ReplicaConfig()):
        self.sim = sim
        self.id = rid
        self.region = region
        # copy; backend reads it LIVE so straggler demotion applies at once
        self.cfg = dataclasses.replace(cfg)
        self.backend = CostModelBackend(self.cfg)
        self.core = ReplicaCore(ReplicaCoreConfig(
            page_size=1, n_pages=cfg.kv_budget, max_batch=cfg.max_batch,
            max_seq_len=cfg.max_seq_len, prefill_chunk=cfg.prefill_chunk,
            preemption=cfg.preemption,
            host_pages=cfg.host_kv_budget,
            discipline=cfg.discipline, cache_discount=cfg.cache_discount,
            shed_deadline=cfg.shed_deadline), self.backend)
        self._stepping = False
        self.alive = True
        self.draining = False
        self._drained_cb: Optional[Callable] = None
        # where rejected-at-the-door requests go while draining (the fleet
        # system points this back at a live LB so nothing is dropped)
        self.on_bounce: Optional[Callable] = None
        # tokens appended by the core this iteration, synthesized into
        # per-token events on the event clock when the iteration completes
        self._tokbuf: list = []
        self.core.token_sink = (
            lambda seq, tok, idx: self._tokbuf.append((seq, tok, idx)))

    # ---- introspection (what probes see)
    def pending_count(self) -> int:
        return self.core.pending_count()

    def outstanding(self) -> int:
        return self.core.outstanding()

    def kv_utilization(self) -> float:
        return self.core.kv_utilization()

    # ---- core state / stats pass-throughs
    @property
    def pending(self):
        return self.core.pending

    @property
    def running(self):
        return self.core.running

    @property
    def radix(self):
        return self.core.radix

    @property
    def peak_outstanding(self) -> int:
        return self.core.peak_outstanding

    @property
    def total_prefill_tokens(self) -> int:
        return self.core.total_prefill_tokens

    @property
    def total_cached_tokens(self) -> int:
        return self.core.total_cached_tokens

    @property
    def completions(self) -> int:
        return self.core.completions

    # ---- request entry
    def enqueue(self, req: Request) -> None:
        if req.cancelled is not None:
            # the cancel raced this request onto the wire: resolve it here,
            # exactly once (it is in nobody's queue anymore)
            if req.finished is None:
                resolve_cancelled(req, self.sim.now)
            return
        if self.draining or not self.alive:
            # a drained replica finishes what it HAS but admits nothing new;
            # requests already on the wire when the drain began bounce back
            # for re-routing instead of being dropped
            if self.on_bounce is not None:
                self.on_bounce(req)
                return
            req.error = f"replica {self.id} is draining"
            req.finished = self.sim.now
            if req.done_cb:
                req.done_cb(req)
            return
        self.core.submit(req)
        self._kick()

    # ---- cancellation (unified front API)
    def cancel(self, rid: int):
        """Abandon a request queued or running here: the core frees its
        pages/radix pins, and the request resolves with the finish_reason
        carried in `req.cancelled` ("cancelled" | "deadline"). Returns the
        reaped Seq, or None if `rid` is not on this replica."""
        seq = self.core.cancel(rid)
        if seq is not None and seq.req.finished is None:
            resolve_cancelled(seq.req, self.sim.now)
        return seq

    # ---- elastic membership (repro.provision)
    def drain(self, on_drained: Optional[Callable] = None) -> None:
        """Graceful decommission: stop admitting, let every in-flight
        request (pending + running) finish, then go dead and fire
        `on_drained(self)`. Contrast `kill()`, which drops in-flight."""
        self.draining = True
        self._drained_cb = on_drained
        # already idle: complete on a fresh event so same-tick enqueues
        # that were delivered before the drain still land first
        self.sim.after(0.0, self._maybe_finish_drain)

    def kill(self) -> None:
        """Hard stop: in-flight work is lost (crash semantics). A drain
        already in progress completes vacuously — its callback must still
        fire, or the caller (fleet controller lease, cost meter) waits
        forever on a replica that will never go idle."""
        self.alive = False
        if self.draining:
            self.sim.after(0.0, self._maybe_finish_drain)

    def _maybe_finish_drain(self) -> None:
        if not self.draining:
            return
        # a dead replica drains vacuously (its in-flight work is already
        # lost) — the callback must still fire or callers wait forever
        if self.alive and (self._stepping or self.core.outstanding() > 0):
            return
        self.alive = False
        self.draining = False
        cb, self._drained_cb = self._drained_cb, None
        if cb is not None:
            cb(self)

    def _kick(self) -> None:
        if not self._stepping and self.alive:
            self._stepping = True
            self.sim.after(0.0, self._step)

    # ---- continuous batching iteration
    def _step(self) -> None:
        if not self.alive:
            self._stepping = False
            return
        plan = self.core.begin_step()
        now = self.sim.now
        for seq in plan.admitted:
            seq.req.replica = self.id
            if seq.req.admit_cb is not None:
                seq.req.admit_cb(seq.req, now)
        for seq in plan.rejected:       # oversized: error result, not HOL wedge
            req: Request = seq.req
            req.error = seq.error
            req.finished = now
            if req.done_cb:
                req.done_cb(req)
        for seq in plan.shed:           # deadline-aware admission refusal
            if seq.req.finished is None:
                resolve_cancelled(seq.req, now, "shed")
        if not self.core.running and not self.core.loading:
            if self.core.pending:       # a rejection callback re-enqueued
                self.sim.after(0.0, self._step)
            else:
                self._stepping = False
                self._maybe_finish_drain()
            return
        dt = self.backend.step_cost(len(self.core.running))
        self.sim.after(dt, lambda a=plan.admitted: self._finish_step(a))

    def _finish_step(self, admitted: list) -> None:
        finished = self.core.finish_step()
        now = self.sim.now
        # synthesize this iteration's token events on the event clock (one
        # drain per step, mirroring the engine's one-host-sync-per-step)
        if self._tokbuf:
            buf, self._tokbuf = self._tokbuf, []
            for seq, tok, idx in buf:
                req = seq.req
                if req.token_cb is not None and req.finished is None:
                    req.token_cb(req, tok, idx, now)
        for seq in admitted:
            if seq.req.ttft is None:
                seq.req.ttft = now
        for seq in finished:
            req: Request = seq.req
            req.finished = now
            if req.done_cb:
                req.done_cb(req)
        if self.core.running or self.core.pending or self.core.loading:
            self.sim.after(0.0, self._step)
        else:
            self._stepping = False
            self._maybe_finish_drain()


# ------------------------------------------------------------------ network

class Network:
    """One-way latencies; RTT matrix keyed by region pairs."""
    DEFAULT_RTT = {
        ("us", "eu"): 0.140, ("us", "asia"): 0.180, ("eu", "asia"): 0.200,
    }

    def __init__(self, rtt: Optional[dict] = None, local_rtt: float = 0.004,
                 wan_gbps: float = 1.0):
        self.rtt = dict(self.DEFAULT_RTT)
        if rtt:
            self.rtt.update(rtt)
        self.local_rtt = local_rtt
        self.wan_gbps = wan_gbps        # inter-region KV transfer bandwidth
        self._warned_pairs: set = set()

    def one_way(self, a: str, b: str) -> float:
        if a == b:
            return self.local_rtt / 2
        key = (a, b) if (a, b) in self.rtt else (b, a)
        if key not in self.rtt:
            pair = frozenset((a, b))        # direction-independent dedup
            if pair not in self._warned_pairs:
                self._warned_pairs.add(pair)
                warnings.warn(
                    f"Network: no RTT configured for region pair {a}<->{b}; "
                    f"assuming 0.15 s RTT", stacklevel=2)
            return 0.15 / 2
        return self.rtt[key] / 2

    def kv_transfer_s(self, a: str, b: str, nbytes: float) -> float:
        """Latency of pulling `nbytes` of KV pages from region b to a: one
        request/response round trip, then the payload at WAN bandwidth."""
        return 2 * self.one_way(a, b) + nbytes / (self.wan_gbps * 1e9)


# ------------------------------------------------------------------ LB

# LB behaviour is configured by the transport-agnostic RoutingConfig; the
# old name stays as an alias for existing callers/tests.
LBConfig = RoutingConfig

# hedge clones get rids from a range no workload generator uses, so a
# clone's cancel can never pull someone else's request out of a queue
_HEDGE_RID = itertools.count(1_000_000_000)


class _SimTransport:
    """WAN transport for RoutingCore: one-way latencies from `Network`,
    delivery as discrete events on the shared `Sim` clock."""

    def __init__(self, lb: "LoadBalancerSim"):
        self.lb = lb

    def now(self) -> float:
        return self.lb.sim.now

    def target_alive(self, target_id: str) -> bool:
        r = self.lb.replicas.get(target_id)
        return r is not None and r.alive

    def peer_alive(self, peer_id: str) -> bool:
        p = self.lb.remote_lbs.get(peer_id)
        return p is not None and p.alive

    def deliver(self, req: Request, target_id: str) -> None:
        r = self.lb.replicas.get(target_id)
        if r is None:
            # target decommissioned between the eligibility check and the
            # send (elastic membership): requeue instead of crashing
            self.lb.sim.after(0.0, lambda: self.lb.on_request(req))
            return
        self.lb.sim.after(self.lb.net.one_way(self.lb.region, r.region),
                          lambda: r.enqueue(req))

    def forward(self, req: Request, peer_id: str) -> None:
        peer = self.lb.remote_lbs[peer_id]
        if self.lb.metrics is not None:
            self.lb.metrics.forwards.append(
                (self.lb.sim.now, self.lb.id, peer_id))
        self.lb.sim.after(self.lb.net.one_way(self.lb.region, peer.region),
                          lambda: peer.on_request(req))

    def steal_request(self, peer_id: str, n: int) -> None:
        victim = self.lb.remote_lbs[peer_id]
        lat = self.lb.net.one_way(self.lb.region, victim.region)
        self.lb.sim.after(lat, lambda: victim.on_steal_request(self.lb, n))

    def shed(self, req: Request) -> None:
        """LB-level deadline-aware admission refusal: resolve immediately
        with finish_reason "shed" — no replica ever sees the request."""
        if req.finished is None:
            resolve_cancelled(req, self.lb.sim.now, "shed")

    def pull_pages(self, req: Request, peer_id: str, target_id: str,
                   prefix_len: int, pull_tokens: int) -> None:
        """Pull-prefix: after the WAN round trip + KV bytes at bandwidth,
        install the prefix into the local replica's radix and deliver the
        request there. The sim models the transferred pages by injecting
        the token prefix directly (page_size=1: tokens are pages); the
        replica's next admission then matches them as device-cached.
        Optimistic in one way the real router is not: the peer's pages are
        assumed still resident at arrival (its trie said so one remote
        heartbeat ago)."""
        peer = self.lb.remote_lbs[peer_id]
        cost = self.lb.cfg.kv_params
        bytes_per = (cost.kv_bytes_per_token if cost is not None
                     else 131072.0)
        lat = self.lb.net.kv_transfer_s(self.lb.region, peer.region,
                                        pull_tokens * bytes_per)
        prefix = tuple(req.prompt_tokens)[:prefix_len]

        def _land() -> None:
            r = self.lb.replicas.get(target_id)
            if r is None or not r.alive:
                # target died while the pages were on the WAN: requeue
                self.lb.on_request(req)
                return
            if prefix:
                r.core.inject_prefix(prefix)
            r.enqueue(req)

        self.lb.sim.after(lat, _land)

    # ---- hedged dispatch (tail-TTFT insurance for the `latency` class)
    def hedge(self, req: Request, peer_id: str) -> None:
        """Duplicate `req` to a second region: a clone (fresh rid, no
        deadline, marked forwarded so it can't re-forward) races the
        primary, FIRST TOKEN WINS, and the loser is reaped through the
        exactly-once cancel path (the travelling `cancelled` flag covers
        a loser caught mid-WAN / mid-steal / mid-pull). If the clone wins,
        its stream and terminal state surface through the PRIMARY request
        object, so the frontend sees one rid-consistent lifecycle either
        way. The loser's burned compute (uncached prefill + decoded
        tokens) is charged to `RunMetrics.wasted_work_tok`."""
        peer = self.lb.remote_lbs[peer_id]
        clone = dataclasses.replace(
            req, rid=next(_HEDGE_RID), deadline_s=None, forwarded=True,
            arrival=0.0, origin_lb=None, ttft=None, finished=None,
            cached_tokens=0, replica=None, error=None, cancelled=None,
            finish_reason=None, admit_cb=None, token_cb=None, done_cb=None)
        m = self.lb.metrics
        if m is not None:
            m.hedged += 1
        orig_token = req.token_cb
        orig_done = req.done_cb
        state = {"winner": None}

        def decide(who: Request) -> None:
            if state["winner"] is not None:
                return
            state["winner"] = who
            if who is clone and m is not None:
                m.hedge_wins += 1
            self._reap_hedge_loser(req if who is clone else clone)

        def primary_token(r, tok, idx, t):
            decide(req)
            if state["winner"] is req:
                if orig_token is not None:
                    orig_token(req, tok, idx, t)
            elif m is not None:
                m.wasted_work_tok += 1

        def clone_token(r, tok, idx, t):
            decide(clone)
            if state["winner"] is clone:
                if orig_token is not None:
                    orig_token(req, tok, idx, t)
            elif m is not None:
                m.wasted_work_tok += 1

        def primary_done(r):
            if state["winner"] is None:
                decide(req)         # finished without a token (error path)
            if state["winner"] is req:
                if orig_done is not None:
                    orig_done(req)
            else:
                # the primary was reaped as the hedge loser; the clone's
                # completion surfaces through this object, so clear the
                # bogus terminal state the cancel path stamped on it
                req.finished = None
                req.finish_reason = None

        def clone_done(r):
            if state["winner"] is None:
                decide(clone)
            if state["winner"] is clone:
                req.ttft = clone.ttft
                req.finished = clone.finished
                req.cached_tokens = clone.cached_tokens
                req.replica = clone.replica
                req.error = clone.error
                req.finish_reason = clone.finish_reason
                if orig_done is not None:
                    orig_done(req)
            # clone lost: its cancel resolution ends here, exactly once

        req.token_cb, req.done_cb = primary_token, primary_done
        clone.token_cb, clone.done_cb = clone_token, clone_done
        self.lb.sim.after(self.lb.net.one_way(self.lb.region, peer.region),
                          lambda: peer.on_request(clone))

    def _reap_hedge_loser(self, loser: Request) -> None:
        """Cancel the losing leg wherever it is: some LB queue, some
        replica (pending/running/loading), or the WAN. The `cancelled`
        flag is set FIRST so a loser in flight (forward, steal handoff,
        pull-prefix landing) resolves itself at arrival."""
        loser.cancelled = "hedge"
        if loser.finished is not None:
            return
        lbs = [self.lb] + list(self.lb.remote_lbs.values())
        for lb in lbs:
            if lb.core.cancel(loser.rid):
                if loser.finished is None:
                    resolve_cancelled(loser, self.lb.sim.now)
                return
            for r in lb.replicas.values():
                seq = r.cancel(loser.rid)
                if seq is not None:
                    # compute the loser burned before the reap: uncached
                    # prefill (if it was admitted) + any decoded tokens —
                    # all spent, none delivered
                    if self.lb.metrics is not None:
                        waste = len(seq.out)
                        if seq.admit_index >= 0:
                            waste += max(0, seq.prompt_len
                                         - seq.req.cached_tokens)
                        self.lb.metrics.wasted_work_tok += waste
                    return


class LoadBalancerSim:
    """Simulator host for the shared `repro.routing.RoutingCore`: schedules
    heartbeat probes as discrete events, builds TargetViews from live
    ReplicaSims / peer LBs, and moves requests over the simulated WAN.
    All routing DECISIONS (eligibility, two-layer dispatch, optimism
    accounting, stealing) live in the core — shared with the real-engine
    `InProcessRouter`."""

    def __init__(self, sim: Sim, lid: str, region: str, net: Network,
                 policy: Policy, remote_policy: Optional[Policy] = None,
                 cfg: Optional[LBConfig] = None, metrics=None):
        self.sim = sim
        self.id = lid
        self.region = region
        self.net = net
        self.policy = policy
        self.remote_policy = remote_policy
        # copy: a caller-held (or default) config instance must never be
        # shared mutable state between LBs
        self.cfg = dataclasses.replace(cfg) if cfg is not None else LBConfig()
        self.replicas: dict[str, ReplicaSim] = {}
        self.remote_lbs: dict[str, "LoadBalancerSim"] = {}
        self.alive = True
        self.metrics = metrics
        self.core = RoutingCore(lid, policy, remote_policy, self.cfg,
                                _SimTransport(self))
        # heartbeat chains die while the LB is dead; each revive() bumps the
        # epoch so stale chains can't double-fire after recovery
        self._hb_epoch = 0
        self._start_probes()

    def _start_probes(self) -> None:
        epoch = self._hb_epoch
        self.sim.after(0.0, lambda: self._probe(epoch))
        self.sim.after(0.0, lambda: self._probe_remote(epoch))

    def revive(self) -> None:
        """Bring a dead LB back: restart the heartbeat loops (they exited
        while alive was False, so flipping the flag alone leaves snapshots
        permanently stale)."""
        self.alive = True
        self._hb_epoch += 1
        self._start_probes()

    # ---- routing state lives in the core
    @property
    def queue(self) -> deque:
        return self.core.queue

    @property
    def forwarded_out(self) -> int:
        return self.core.forwarded_out

    @property
    def peak_queue(self) -> int:
        return self.core.peak_queue

    # ---- topology
    def add_replica(self, r: ReplicaSim) -> None:
        self.replicas[r.id] = r
        self.core.target_added(self._view_of(r))

    def remove_replica(self, rid: str) -> Optional[ReplicaSim]:
        """Idempotent: routing state (prefix-trie records, hashring vnodes,
        probe snapshot) is forgotten exactly once, on the removal that
        actually owned the replica — repeated removals are no-ops."""
        r = self.replicas.pop(rid, None)
        if r is not None:
            self.core.target_removed(rid)
        return r

    def peer(self, lb: "LoadBalancerSim") -> None:
        if lb.id != self.id:
            self.remote_lbs[lb.id] = lb
            self.core.peer_added(lb.id)

    # ---- availability monitor (Alg.1 MonitorAvailability)
    def _view_of(self, r: ReplicaSim) -> TargetView:
        return TargetView(id=r.id, outstanding=r.outstanding(),
                          pending=r.pending_count(),
                          available=r.pending_count() == 0 and r.alive,
                          tenant_counters=(r.core.tenant_counters() or None
                                           if self.cfg.fairness else None))

    def n_avail_replicas(self) -> int:
        return sum(1 for r in self.replicas.values()
                   if r.pending_count() == 0 and r.alive)

    def _probe(self, epoch: int = 0) -> None:
        if not self.alive or epoch != self._hb_epoch:
            return
        self.core.refresh_local(
            [self._view_of(r) for r in self.replicas.values()])
        self.core.maybe_steal()
        self.sim.after(self.cfg.probe_interval, lambda: self._probe(epoch))

    def _probe_remote(self, epoch: int = 0) -> None:
        """WAN heartbeat: refresh peer-LB snapshots (slower than local)."""
        if not self.alive or epoch != self._hb_epoch:
            return
        self.core.refresh_remote([
            TargetView(
                id=lid, available=True,
                n_avail_replicas=lb.n_avail_replicas(),
                n_replicas=len(lb.replicas),
                queue_len=len(lb.queue),
                outstanding=sum(x.outstanding()
                                for x in lb.replicas.values()),
                tenant_counters=lb.core.tenant_snapshot())
            if lb.alive else TargetView.unavailable(lid)
            for lid, lb in self.remote_lbs.items()])
        self.sim.after(self.cfg.remote_probe_interval,
                       lambda: self._probe_remote(epoch))

    # ---- work stealing (beyond-paper; receiver-initiated rebalancing)
    def on_steal_request(self, thief: "LoadBalancerSim", n: int) -> None:
        if not self.alive:
            return
        lat = self.net.one_way(self.region, thief.region)
        for req in self.core.release_for_steal(n, thief.id):
            if self.metrics is not None:
                self.metrics.forwards.append((self.sim.now, self.id,
                                              f"steal->{thief.id}"))
            self.sim.after(lat, lambda q=req: thief.on_request(q))

    # ---- request path (Alg.1 HandleRequest)
    def on_request(self, req: Request) -> None:
        if req.cancelled is not None:
            # cancel raced the request onto the WAN (forward / steal /
            # failover handoff): resolve at arrival, exactly once
            if req.finished is None:
                resolve_cancelled(req, self.sim.now)
            return
        if req.arrival == 0.0:
            req.arrival = self.sim.now
        if req.origin_lb is None:
            req.origin_lb = self.id
        self.core.on_request(req)


# ------------------------------------------------------------------ controller

class Controller:
    """Centralized controller (§4.2): health-probes LBs, reassigns a dead
    LB's replicas to the geographically closest live LB, returns them on
    recovery; demotes stragglers."""

    def __init__(self, sim: Sim, net: Network, lbs: list[LoadBalancerSim],
                 probe_interval: float = 0.2):
        self.sim = sim
        self.net = net
        self.lbs = {lb.id: lb for lb in lbs}
        self.probe_interval = probe_interval
        self.tracker = FailoverTracker()
        self.events: list[tuple[float, str]] = []
        sim.after(probe_interval, self._probe)

    def _closest_live(self, region: str) -> Optional[LoadBalancerSim]:
        live = [lb for lb in self.lbs.values() if lb.alive]
        if not live:
            return None
        return min(live, key=lambda lb: self.net.one_way(region, lb.region))

    def _probe(self) -> None:
        for lb in self.lbs.values():
            if self.tracker.needs_failover(lb.id, lb.alive):
                self._failover(lb)
            elif self.tracker.needs_restore(lb.id, lb.alive):
                self._restore(lb)
        self.sim.after(self.probe_interval, self._probe)

    def _failover(self, dead: LoadBalancerSim) -> None:
        host = self._closest_live(dead.region)
        if host is None:
            return
        self.tracker.record_failover(dead.id, list(dead.replicas.items()))
        for rid in list(dead.replicas):
            r = dead.remove_replica(rid)
            if r is not None:
                host.add_replica(r)
        # drain the dead LB's queue to the host as well
        while dead.queue:
            req = dead.queue.popleft()
            self.sim.after(self.net.one_way(dead.region, host.region),
                           lambda q=req: host.on_request(q))
        self.events.append((self.sim.now, f"failover {dead.id} -> {host.id}"))

    def _restore(self, lb: LoadBalancerSim) -> None:
        """Reclaim the replicas whose HOME this LB is, from wherever
        cascading failovers moved them since."""
        for rid, r in self.tracker.reclaimable(lb.id):
            owner = next((x for x in self.lbs.values()
                          if rid in x.replicas), None)
            if owner is None or owner is lb:   # removed meanwhile / already home
                continue
            owner.remove_replica(rid)
            lb.add_replica(r)
        self.tracker.mark_restored(lb.id)
        self.events.append((self.sim.now, f"restore {lb.id}"))

    def fail_lb(self, lbid: str) -> None:
        self.lbs[lbid].alive = False

    def recover_lb(self, lbid: str) -> None:
        self.lbs[lbid].revive()

    def mark_straggler(self, replica: ReplicaSim, factor: float) -> None:
        replica.cfg.speed_factor = factor
