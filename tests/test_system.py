"""End-to-end behaviour of the named system variants (paper Fig. 8/9/10
mechanisms at small scale): completion, cross-region offload, failover
under load, determinism, and the cost model."""
from __future__ import annotations

import pytest

from repro.provision.cost import (autoscale_on_demand_cost,
                                  global_peak_cost, region_local_cost,
                                  replicas_needed)
from repro.core.simulator import ReplicaConfig
from repro.core.system import ServingSystem
from repro.core.workloads import diurnal_series, multiturn, tot

RCFG = ReplicaConfig(kv_budget=8192)


def _run(variant, counts=None, horizon=120.0, rpr=None, seed=0, rcfg=RCFG,
         turns=4):
    sys = ServingSystem(variant, rpr or {"us": 2, "eu": 2, "asia": 2},
                        replica_cfg=rcfg, seed=seed)
    for s in multiturn(counts or {"us": 6, "eu": 3, "asia": 3},
                       turns=turns, seed=seed):
        sys.add_session_client(s, think_mean=0.2)
    return sys, sys.run(until=horizon)


@pytest.mark.parametrize("variant", ["skylb", "skylb-ch", "rr", "ll", "ch",
                                     "sgl", "gke", "region-local", "blend"])
def test_variant_completes_requests(variant):
    _, s = _run(variant)
    assert s["requests"] > 0
    assert s["throughput_tok_s"] > 0
    assert s["ttft_p50"] > 0


def test_skylb_forwards_under_skew():
    sys, s = _run("skylb", counts={"us": 16, "eu": 2, "asia": 2})
    assert s["forwards"] > 0
    assert sys.lbs["lb-us"].forwarded_out > 0


def test_region_local_never_forwards():
    _, s = _run("region-local", counts={"us": 16, "eu": 2, "asia": 2})
    assert s["forwards"] == 0


def test_deterministic_same_seed():
    _, s1 = _run("skylb", seed=5)
    _, s2 = _run("skylb", seed=5)
    assert s1["requests"] == s2["requests"]
    assert s1["throughput_tok_s"] == pytest.approx(s2["throughput_tok_s"])
    assert s1["ttft_p50"] == pytest.approx(s2["ttft_p50"])


def test_skylb_beats_region_local_on_skew():
    _, sky = _run("skylb", counts={"us": 16, "eu": 2, "asia": 2},
                  horizon=180.0, turns=8)
    _, loc = _run("region-local", counts={"us": 16, "eu": 2, "asia": 2},
                  horizon=180.0, turns=8)
    assert sky["throughput_tok_s"] >= 0.98 * loc["throughput_tok_s"]
    assert sky["ttft_p50"] <= loc["ttft_p50"]


def test_lb_failure_recovery_under_load():
    sys = ServingSystem("skylb", {"us": 2, "eu": 2}, replica_cfg=RCFG)
    for s in multiturn({"us": 4, "eu": 4}, turns=4):
        sys.add_session_client(s, think_mean=0.2)
    sys.sim.after(5.0, lambda: sys.controller.fail_lb("lb-eu"))
    sys.sim.after(30.0, lambda: sys.controller.recover_lb("lb-eu"))
    summary = sys.run(until=150.0)
    assert summary["requests"] > 0
    assert any("failover" in e for _, e in sys.controller.events)
    assert any("restore" in e for _, e in sys.controller.events)
    # eu replicas are back home after recovery
    assert len(sys.lbs["lb-eu"].replicas) == 2


def test_straggler_demotion():
    sys = ServingSystem("skylb", {"us": 2}, replica_cfg=RCFG)
    victim = sys.replicas[0]
    sys.controller.mark_straggler(victim, factor=5.0)
    for s in multiturn({"us": 8}, turns=4):
        sys.add_session_client(s, think_mean=0.2)
    sys.run(until=120.0)
    other = sys.replicas[1]
    assert other.completions > victim.completions    # SP-P avoids the slow one


def test_session_client_stops_on_rejection():
    """An oversized turn is rejected ONCE and ends the session (history only
    grows, so retrying every later turn would just re-fail)."""
    from repro.core.workloads import SessionSpec, Turn, _tokens
    import random as _random
    rng = _random.Random(0)
    sys = ServingSystem("skylb", {"us": 1},
                        replica_cfg=ReplicaConfig(kv_budget=300))
    turns = [Turn(prompt_suffix=_tokens(rng, 50),
                  output_tokens=_tokens(rng, 100)) for _ in range(4)]
    sys.add_session_client(SessionSpec("u0", "us", _tokens(rng, 100), turns),
                           think_mean=0.1)
    s = sys.run(until=60.0)
    # turn 1: 150+100=250 <= 300 served; turn 2: 300+100=400 rejected; stop
    assert s["requests"] == 1
    assert s["rejected"] == 1


def test_tot_client_tree_semantics():
    sys = ServingSystem("skylb", {"us": 2}, replica_cfg=RCFG)
    trees = tot({"us": 2}, branching=2, depth=3, trees_per_client=1)
    for t in trees:
        sys.add_tot_client(t)
    s = sys.run(until=120.0)
    assert s["requests"] == 2 * 7        # 2 clients x (1+2+4) nodes


# ------------------------------------------------------------- cost model

def test_cost_model_orderings():
    series = diurnal_series(("us", "eu", "asia", "sa", "oceania"))
    series = {r: [x * 100 for x in xs] for r, xs in series.items()}
    kappa = 20.0
    local = region_local_cost(series, kappa)
    glob = global_peak_cost(series, kappa)
    od = autoscale_on_demand_cost(series, kappa)
    assert glob < local                 # aggregation always saves
    assert od > glob                    # on-demand premium dominates
    assert replicas_needed(0.0, kappa) == 1
    assert replicas_needed(45.0, 20.0) == 3
