"""Cancellation edge cases across the stack: cancel while pending, cancel
mid-generation after a chunked prefill (pages + radix pins freed, allocator
balance restored), cancel racing a cross-region steal (resolves exactly
once), cancel after finish (no-op), and deadline handling (already expired
at submit -> immediate DEADLINE, nothing dispatched; expiry mid-run ->
abort on the sim clock)."""
from __future__ import annotations

import pytest

from repro.core.simulator import ReplicaConfig, Request
from repro.core.system import ServingSystem
from repro.frontend.api import RequestState
from repro.replica import CostModelBackend, ReplicaCore, ReplicaCoreConfig
from repro.serving.request import FinishReason, GenRequest, SamplingParams

RCFG = ReplicaConfig(kv_budget=8192)


def _req(sys, rid, region="us", prompt_len=32, out_len=8, user="u", **kw):
    return Request(rid=rid, user_id=user, session_key=f"{user}{rid}",
                   region=region, prompt_tokens=tuple(range(prompt_len)),
                   output_len=out_len, output_tokens=tuple(range(out_len)),
                   **kw)


def _gen(rid, prompt, max_new, **kw):
    return GenRequest(prompt_tokens=tuple(prompt), rid=rid,
                      sampling=SamplingParams(max_new_tokens=max_new), **kw)


# ------------------------------------------------------------ core level

def test_core_cancel_while_pending():
    core = ReplicaCore(ReplicaCoreConfig(page_size=4, n_pages=32,
                                         max_batch=1), CostModelBackend())
    core.submit(_gen(0, range(8), 8))
    core.submit(_gen(1, range(100, 108), 8))      # waits behind max_batch=1
    core.begin_step()
    assert len(core.pending) == 1
    used_before = core.alloc.used_pages
    seq = core.cancel(1)
    assert seq is not None and not core.pending
    assert core.alloc.used_pages == used_before   # pending held no pages
    assert core.cancellations == 1
    # the cancelled rid is gone for good: cancel again is a no-op
    assert core.cancel(1) is None


def test_core_cancel_mid_generation_restores_allocator_balance():
    """Cancel a running sequence admitted through CHUNKED prefill over a
    radix-cached prefix: its fresh pages free, its pins on the cached
    prefix release (pages drop back to tree-only refs, i.e. evictable),
    and the allocator balance is exactly what it was pre-admission."""
    core = ReplicaCore(ReplicaCoreConfig(page_size=4, n_pages=64,
                                         prefill_chunk=8),
                       CostModelBackend())
    # seed the radix: run a request to completion so its pages are cached
    core.submit(_gen(0, range(16), 8))
    while core.running or core.pending:
        core.begin_step()
        core.finish_step()
    cached_pages = core.radix.cached_pages
    assert cached_pages > 0
    used_baseline = core.alloc.used_pages         # tree-only refs
    # same 16-token prefix + a long disjoint tail -> chunked prefill
    # (8-token chunks) over a radix hit that gets ref-pinned at admission
    core.submit(_gen(1, tuple(range(16)) + tuple(range(200, 224)), 16))
    core.begin_step()                              # admit + chunked prefill
    seq = core.running[0]
    assert seq.cached_pages > 0                    # pinned a cached prefix
    pinned = seq.pages[:seq.cached_pages]
    assert all(core.alloc.refcount(p) == 2 for p in pinned)  # tree + seq
    core.finish_step()
    core.begin_step()                              # a few decode steps
    core.finish_step()
    assert core.cancel(1) is not None
    assert not core.running
    # pins released: cached pages are tree-only again, fresh pages freed
    assert all(core.alloc.refcount(p) == 1 for p in pinned)
    assert core.alloc.used_pages == used_baseline
    # no pin left anywhere: the whole cached chain can be evicted away
    n = core.radix.cached_pages
    assert core.radix.evict(n) == n
    assert core.alloc.used_pages == 0


def test_core_cancel_after_finish_noop():
    core = ReplicaCore(ReplicaCoreConfig(page_size=4, n_pages=32),
                       CostModelBackend())
    core.submit(_gen(0, range(8), 4))
    while core.running or core.pending:
        core.begin_step()
        core.finish_step()
    assert core.completions == 1
    assert core.cancel(0) is None
    assert core.cancellations == 0


# ------------------------------------------------------------ sim level

def test_sim_cancel_mid_decode_resolves_and_frees():
    sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
    done = []
    h = sys.submit(_req(sys, 0, out_len=64), done.append)
    sys.sim.after(0.5, lambda: sys.cancel(0))     # mid-decode by then
    sys.run(until=30.0)
    assert len(done) == 1 and done[0].finish_reason == "cancelled"
    assert h.state is RequestState.CANCELLED
    assert h.result.finish_reason is FinishReason.CANCELLED
    # partial stream was delivered, then stopped
    assert 0 < len(h.events) < 64
    core = sys.replicas[0].core
    assert not core.running and not core.pending
    # every page the request held was freed (radix may keep cached pages)
    assert core.alloc.used_pages == core.radix.cached_pages
    s = sys.metrics.summary(sys.replicas)
    assert s["cancelled"] == 1 and s["unresolved"] == 0
    assert s["requests"] == 0                     # not counted as served


def test_sim_cancel_while_queued_at_lb():
    """With zero capacity the request never leaves the LB queue: cancel
    must pull it out of the routing core directly."""
    sys = ServingSystem(
        "skylb", {"us": 1},
        replica_cfg=ReplicaConfig(kv_budget=8192, max_batch=2))
    # wedge the replica so the LB keeps the next request queued (SP-P:
    # pending>0 -> not eligible)
    for i in range(8):
        sys.submit(_req(sys, i, out_len=512))
    sys.run(until=0.2)
    victim = _req(sys, 99, out_len=8)
    done = []
    h = sys.submit(victim, done.append)
    sys.run(until=0.4)
    lb = sys.lbs["lb-us"]
    assert any(r.rid == 99 for r in lb.core.queue)
    assert sys.cancel(99) is True
    assert not any(r.rid == 99 for r in lb.core.queue)
    sys.run(until=0.6)
    assert len(done) == 1 and done[0].finish_reason == "cancelled"
    assert h.state is RequestState.CANCELLED and h.events == []


def test_sim_cancel_racing_steal_resolves_exactly_once():
    """A cancel that lands while the request is on the WAN between the
    steal release and the thief's arrival must resolve exactly once, at
    arrival."""
    sys = ServingSystem("steal", {"us": 1, "eu": 1}, replica_cfg=RCFG)
    victim_lb, thief_lb = sys.lbs["lb-us"], sys.lbs["lb-eu"]
    done = []
    req = _req(sys, 0, out_len=8)
    sys.submit(req, done.append)
    # park the request in the victim LB's queue (bypass dispatch timing)
    victim_lb.core.queue.append(req)
    victim_lb.core.cfg.steal_threshold = 0
    released = victim_lb.core.release_for_steal(1, thief_lb.id)
    assert released == [req]                      # on the WAN now
    sys.sim.after(0.07, lambda: thief_lb.on_request(req))
    assert sys.cancel(0) is True                  # in nobody's queue: flag
    assert sys.cancel(0) is False                 # second cancel: no-op
    sys.run(until=5.0)
    assert len(done) == 1                         # resolved exactly once
    assert done[0].finish_reason == "cancelled"
    assert not thief_lb.core.queue                # never (re)queued
    assert all(r.completions == 0 for r in sys.replicas)


def test_sim_cancel_after_finish_noop():
    sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
    done = []
    h = sys.submit(_req(sys, 0, out_len=4), done.append)
    sys.run(until=30.0)
    assert len(done) == 1 and done[0].finish_reason is None
    assert h.state is RequestState.FINISHED
    assert sys.cancel(0) is False                 # terminal: no-op
    assert h.cancel() is False
    assert len(done) == 1
    s = sys.metrics.summary()
    assert s["cancelled"] == 0 and s["requests"] == 1


# ------------------------------------------- fairness vs cancellation

def test_core_cancel_no_refund_but_counters_not_stuck():
    """Under VTC a cancelled request refunds NOTHING (the charge for work
    already done stands — counters only move forward), but the tenant's
    live-request tracking must retire the rid: the tenant can go idle and
    re-enter through the lift rule instead of being pinned 'active'."""
    core = ReplicaCore(ReplicaCoreConfig(page_size=4, n_pages=64,
                                         discipline="vtc"),
                       CostModelBackend())
    core.submit(_gen(0, range(16), 32, user_id="a"))
    core.begin_step()                 # admit: "a" charged 16 uncached
    core.finish_step()                # + decode appends
    charged = core.discipline.counters()["a"]
    assert charged >= 16.0
    assert core.cancel(0) is not None
    # no refund -- but the rid is retired, so "a" is idle again
    assert core.discipline.counters()["a"] == charged
    assert core.discipline._active["a"] == set()
    # tenant "b" is served on; "a" re-enters AT THE FLOOR (lift rule), so
    # the cancelled work neither refunds nor permanently handicaps "a"
    core.submit(_gen(1, range(100, 116), 4, user_id="b"))
    while core.running or core.pending:
        core.begin_step()
        core.finish_step()
    core.submit(_gen(2, range(200, 216), 4, user_id="a"))
    assert core.discipline.counters()["a"] == max(
        charged, min(core.discipline.counters().values()))


def test_core_cancel_while_pending_vtc_never_charged():
    """A request cancelled before admission was never served: no charge at
    all, and the discipline forgets its rid (idempotently)."""
    core = ReplicaCore(ReplicaCoreConfig(page_size=4, n_pages=32,
                                         max_batch=1, discipline="vtc"),
                       CostModelBackend())
    core.submit(_gen(0, range(8), 8, user_id="a"))
    core.submit(_gen(1, range(100, 108), 8, user_id="b"))  # waits pending
    core.begin_step()
    assert core.cancel(1) is not None
    assert core.discipline.counters().get("b", 0.0) == 0.0
    assert core.discipline._active["b"] == set()
    assert core.cancel(1) is None     # second cancel: no-op, nothing stuck
    while core.running or core.pending:
        core.begin_step()
        core.finish_step()
    assert core.completions == 1


def test_sim_deadline_abort_no_refund_vtc():
    """A deadline abort mid-decode exits through the same no-refund path:
    the tenant keeps its charge, the replica keeps no live-rid residue,
    and later traffic schedules normally."""
    sys = ServingSystem(
        "skylb", {"us": 1},
        replica_cfg=ReplicaConfig(kv_budget=8192, discipline="vtc"))
    done = []
    sys.submit(_req(sys, 0, out_len=64, user="a", deadline_s=0.5),
               done.append)
    sys.run(until=5.0)
    assert done[0].finish_reason == "deadline"
    core = sys.replicas[0].core
    charged = core.discipline.counters()["a"]
    assert charged >= 32.0            # prefill charge survives the abort
    assert core.discipline._active["a"] == set()
    ok = []
    sys.submit(_req(sys, 1, out_len=8, user="b"), ok.append)
    sys.run(until=30.0)
    assert ok[0].finish_reason is None
    assert core.discipline.counters()["a"] == charged    # still no refund
    s = sys.metrics.summary(sys.replicas)
    assert s["deadline_aborted"] == 1 and s["unresolved"] == 0


# ------------------------------------------------------------ deadlines

def test_sim_deadline_expired_at_submit_dispatches_nothing():
    sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
    done = []
    h = sys.submit(_req(sys, 0, out_len=8, deadline_s=0.0), done.append)
    sys.run(until=5.0)
    assert h.state is RequestState.DEADLINE
    assert h.result.finish_reason is FinishReason.DEADLINE
    assert h.events == [] and h.result.output_tokens == ()
    assert len(done) == 1 and done[0].finish_reason == "deadline"
    # nothing was dispatched: no LB queue traffic, no replica work
    assert sys.replicas[0].core.total_prefill_tokens == 0
    assert sys.replicas[0].core.steps == 0
    s = sys.metrics.summary()
    assert s["deadline_aborted"] == 1 and s["unresolved"] == 0


def test_sim_deadline_expires_mid_run_aborts_on_the_sim_clock():
    sys = ServingSystem("skylb", {"us": 1}, replica_cfg=RCFG)
    done = []
    # out_len=64 at ~30 tok/s needs ~2s; the 0.5 s deadline fires first
    h = sys.submit(_req(sys, 0, out_len=64, deadline_s=0.5), done.append)
    ok = []
    sys.submit(_req(sys, 1, out_len=8), ok.append)  # no deadline: completes
    sys.run(until=30.0)
    assert h.state is RequestState.DEADLINE
    assert done[0].finish_reason == "deadline"
    assert done[0].finished == pytest.approx(0.5, abs=1e-6)
    assert len(ok) == 1 and ok[0].finish_reason is None
    s = sys.metrics.summary(sys.replicas)
    assert s["deadline_aborted"] == 1 and s["requests"] == 1
    assert s["unresolved"] == 0
    # goodput counts only the request that met its deadline
    assert s["goodput_tok_s"] == pytest.approx(8 / s["duration_s"])
