"""Sim <-> engine replica parity: `CostModelBackend` and `JaxPagedBackend`
drive the SAME `ReplicaCore` logic, so on a shared deterministic request
trace they must make byte-identical scheduling decisions — admission order,
cached-token counts, evicted page ids, rejections, and preemptions. This
mirrors PR 1's routing parity test one layer down.

Generated tokens differ between backends (the cost model replays
predetermined completions, the engine samples real logits), so parity holds
exactly when no decision input reads a generated region: the trace keeps
every prompt — and the tokens-so-far of the one preempted/resumed request —
prefix-disjoint from other sequences' generated tokens. (A resumed request
re-matches over prompt + its own generated tokens; if those overlapped a
cached sequence, cached_len could legitimately differ per backend.)
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.replica import (CostModelBackend, CostParams, ReplicaCore,
                           ReplicaCoreConfig)
from repro.serving.jax_backend import JaxPagedBackend
from repro.serving.request import GenRequest, SamplingParams

CFG = ReplicaCoreConfig(page_size=8, n_pages=12, max_batch=3,
                        max_seq_len=256, reserved_pages=1,
                        preemption=True, record_decisions=True)
N_STEPS = 120


def _trace(vocab: int):
    """(step -> [(rid, prompt, max_new, priority)]): exercises preemption,
    cross-request prefix caching, eviction pressure, a fully-cached replay,
    an oversized rejection, and mid-flight cancellation (see CANCELS)."""
    rng = np.random.default_rng(7)
    tok = lambda n: tuple(int(t) for t in rng.integers(1, vocab, size=n))
    base = tok(16)                      # shared prefix for the cache block
    p20, p21 = tok(24), tok(24)         # preemption block (disjoint)
    p0 = base + tok(8)
    p1 = base + tok(12)
    p3 = tok(30)                        # oversized: 130 tokens -> 17 pages
    p40, p41 = tok(24), tok(24)         # cancellation block (disjoint)
    p42 = tok(40)                       # needs 10 of 11 pages: waits pending
    return {
        0: [(20, p20, 32, 0)],
        1: [(21, p21, 16, 1)],          # higher priority -> preempts rid 20
        70: [(0, p0, 8, 0), (1, p1, 8, 0)],
        80: [(2, p0, 8, 0)],            # replay: fully-cached prompt rule
        82: [(3, p3, 100, 0)],          # can never fit -> rejected
        100: [(40, p40, 16, 0), (41, p41, 16, 0)],
        101: [(42, p42, 40, 0)],        # blocked behind 40/41: stays pending
    }


# step -> rids cancelled before that step's begin_step: rid 42 while still
# PENDING, rid 41 MID-DECODE (pages + radix pins freed on both backends)
CANCELS = {102: [42], 104: [41]}


def _drive(core: ReplicaCore, trace: dict) -> dict:
    cached: dict[int, int] = {}
    for step in range(N_STEPS):
        for rid, prompt, max_new, prio in trace.get(step, ()):
            core.submit(GenRequest(
                prompt_tokens=prompt, rid=rid, priority=prio,
                sampling=SamplingParams(max_new_tokens=max_new)))
        for rid in CANCELS.get(step, ()):
            assert core.cancel(rid) is not None
        plan = core.begin_step()
        for seq in plan.admitted:
            cached[seq.req.rid] = seq.req.cached_tokens
        core.finish_step()
    return cached


def test_sim_engine_replica_parity(qwen_reduced, qwen_model_params):
    _, params = qwen_model_params
    trace = _trace(qwen_reduced.vocab)

    core_sim = ReplicaCore(CFG, CostModelBackend())
    cached_sim = _drive(core_sim, trace)

    backend = JaxPagedBackend(qwen_reduced, params, n_pages=CFG.n_pages,
                              page_size=CFG.page_size, prefill_pad=16)
    core_jax = ReplicaCore(CFG, backend)
    backend.bind(core_jax)
    cached_jax = _drive(core_jax, trace)

    # identical decision streams: admission order, cached-token counts,
    # evicted page ids, rejections, preemptions, cancellations
    assert core_sim.decisions == core_jax.decisions
    assert cached_sim == cached_jax

    # the trace actually exercised every decision kind
    kinds = {e[0] for e in core_sim.decisions}
    assert kinds == {"admit", "evict", "reject", "preempt", "cancel"}
    assert ("preempt", 20) in core_sim.decisions
    assert ("reject", 3) in core_sim.decisions
    # rid 42 cancelled while pending (never admitted), rid 41 mid-decode
    assert ("cancel", 42) in core_sim.decisions
    assert ("cancel", 41) in core_sim.decisions
    assert 42 not in cached_sim and 41 in cached_sim
    # replay request hit the cache but re-prefilled the final page
    assert cached_sim[2] == 16

    # both drained completely and agree on totals
    for core in (core_sim, core_jax):
        assert not core.running and not core.pending
    assert core_sim.completions == core_jax.completions == 6
    assert core_sim.rejections == core_jax.rejections == 1
    assert core_sim.preemptions == core_jax.preemptions == 1
    assert core_sim.cancellations == core_jax.cancellations == 2
    assert core_sim.total_cached_tokens == core_jax.total_cached_tokens


def test_spec_mode_replica_parity(qwen_reduced, qwen_model_params):
    """Speculation ON for both backends — the cost model at acceptance
    rate 1.0 vs the JAX engine with drafter == target (every greedy draft
    matches) — must still make byte-identical decisions, now INCLUDING the
    ("accept", rid, n) burst events the speculative step records."""
    _, params = qwen_model_params
    trace = _trace(qwen_reduced.vocab)
    K = 3

    core_sim = ReplicaCore(CFG, CostModelBackend(
        CostParams(spec_k=K, spec_accept_rate=1.0)))
    cached_sim = _drive(core_sim, trace)

    backend = JaxPagedBackend(qwen_reduced, params, n_pages=CFG.n_pages,
                              page_size=CFG.page_size, prefill_pad=16,
                              spec_k=K, draft_cfg=qwen_reduced,
                              draft_params=params)
    core_jax = ReplicaCore(CFG, backend)
    backend.bind(core_jax)
    cached_jax = _drive(core_jax, trace)

    assert core_sim.decisions == core_jax.decisions
    assert cached_sim == cached_jax
    accepts = [d for d in core_sim.decisions if d[0] == "accept"]
    assert accepts and any(n > 1 for _, _, n in accepts)
    assert core_sim.completions == core_jax.completions == 6
    assert core_sim.spec_steps == core_jax.spec_steps > 0
    assert core_sim.spec_tokens == core_jax.spec_tokens > 0
