"""Diurnal cost study (paper §2.2 + Fig. 10): sweep the provisioning
strategies over a 5-region diurnal day and report where the paper's 25%
saving comes from — then validate with the event simulator.

Run:  PYTHONPATH=src python examples/diurnal_cost_study.py
"""
from repro.provision.cost import (autoscale_on_demand_cost,
                                  global_peak_cost, region_local_cost,
                                  variance_stats)
from repro.core.simulator import ReplicaConfig
from repro.core.system import ServingSystem
from repro.core.workloads import diurnal_series, multiturn

REGIONS5 = ("us", "eu", "asia", "sa", "oceania")


def cost_table():
    print("== provisioning cost over one diurnal day (5 regions) ==")
    amps = {"us": 1.0, "eu": 0.8, "asia": 0.9, "sa": 0.25, "oceania": 0.12}
    series = {r: [x * 400 for x in xs] for r, xs in diurnal_series(
        REGIONS5, step_h=0.5, seed=7, amp_by_region=amps).items()}
    var = variance_stats(series)
    print(f"per-region peak/trough: "
          f"{var['per_region_min']:.1f}-{var['per_region_max']:.1f}x; "
          f"aggregated: {var['aggregated']:.2f}x")
    kappa = 40.0
    local = region_local_cost(series, kappa)
    glob = global_peak_cost(series, kappa)
    od = autoscale_on_demand_cost(series, kappa)
    print(f"region-local reserved : ${local:10.0f}")
    print(f"global-peak reserved  : ${glob:10.0f}   "
          f"({1 - glob / local:.1%} saved — needs cross-region routing)")
    print(f"perfect on-demand     : ${od:10.0f}   "
          f"({od / glob:.2f}x the global-reserved cost)")


def capacity_sweep():
    print("\n== SkyLB vs region-local at matched replica counts ==")
    rcfg = ReplicaConfig(kv_budget=16384)

    def drive(variant, n):
        per, rem = n // 3, n % 3
        sys = ServingSystem(variant, {"us": per + rem, "eu": per,
                                      "asia": per}, replica_cfg=rcfg)
        for s in multiturn({"us": 28, "eu": 8, "asia": 8}, turns=10):
            sys.add_session_client(s, think_mean=0.3)
        return sys.run(until=180.0)["throughput_tok_s"]

    base12 = drive("region-local", 12)
    print(f"region-local @12 replicas: {base12:7.1f} tok/s  (baseline)")
    for n in (12, 9, 6):
        sky = drive("skylb", n)
        flag = "  <= matches baseline with " + str(n) + " replicas" \
            if sky >= 0.97 * base12 and n < 12 else ""
        print(f"skylb        @{n:2d} replicas: {sky:7.1f} tok/s "
              f"({sky / base12:5.2f}x){flag}")


if __name__ == "__main__":
    cost_table()
    capacity_sweep()
    print("\ndiurnal_cost_study OK")
