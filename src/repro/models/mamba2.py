"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block in pure JAX.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk state recurrence via lax.scan); decode is the O(1) recurrent
update. State math runs in fp32.

TP note: the reference CUDA implementation fuses z/x/B/C/dt into one in_proj
GEMM. We keep them as separate projections (mathematically identical) so the
head/channel dims shard cleanly over the 'model' mesh axis — sharding a
concatenated mixed dim would misalign split boundaries with shard boundaries.
The depthwise conv is likewise split into its x and BC channel groups (exact,
since depthwise convs are per-channel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import normal_init, rms_norm


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d, di, G, N, H, W = (cfg.d_model, cfg.d_inner, s.n_groups, s.state,
                         cfg.ssm_heads, s.conv_width)
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    dt = jnp.exp(jax.random.uniform(ks[6], (H,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "wz": normal_init(ks[0], (d, di), sc, dtype),
        "wx": normal_init(ks[1], (d, di), sc, dtype),
        "wB": normal_init(ks[2], (d, G * N), sc, dtype),
        "wC": normal_init(ks[3], (d, G * N), sc, dtype),
        "wdt": normal_init(ks[4], (d, H), sc, dtype),
        "conv_w_x": normal_init(ks[5], (W, di), W ** -0.5, dtype),
        "conv_b_x": jnp.zeros((di,), dtype),
        "conv_w_bc": normal_init(ks[7], (W, 2 * G * N), W ** -0.5, dtype),
        "conv_b_bc": jnp.zeros((2 * G * N,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[6], (H,), jnp.float32, 1.0, 16.0)),
        "dt_bias": jnp.log(jnp.expm1(dt)),                      # inv-softplus
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": normal_init(
            ks[4], (di, d), di ** -0.5 / (2 * max(cfg.n_layers, 1)) ** 0.5, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 cache: jax.Array | None = None):
    """Depthwise causal conv1d + silu. x: (B,S,ch); w: (W,ch).
    cache: (B,W-1,ch) previous inputs (decode) or None (prefill, zero-pad).
    Returns (out (B,S,ch), new_cache (B,W-1,ch))."""
    B, S, ch = x.shape
    W = w.shape[0]
    if cache is None:
        cache = jnp.zeros((B, W - 1, ch), x.dtype)
    full = jnp.concatenate([cache, x], axis=1)                   # (B, W-1+S, ch)
    out = jnp.zeros((B, S, ch), jnp.float32)
    for i in range(W):                                           # W is tiny (4)
        out = out + full[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_cache = full[:, -(W - 1):, :]
    return jax.nn.silu(out).astype(x.dtype), new_cache


def _project(p, u, cfg: ModelConfig, conv_x_cache=None, conv_bc_cache=None):
    """u -> (z, x, BC, dt, new conv caches). BC still concatenated (small)."""
    z = u @ p["wz"]
    x = u @ p["wx"]
    BC = jnp.concatenate([u @ p["wB"], u @ p["wC"]], axis=-1)
    dt = u @ p["wdt"]
    x, new_cx = _causal_conv(x, p["conv_w_x"], p["conv_b_x"], conv_x_cache)
    BC, new_cbc = _causal_conv(BC, p["conv_w_bc"], p["conv_b_bc"], conv_bc_cache)
    return z, x, BC, dt, new_cx, new_cbc


def ssd_chunked(x, dt, a, B_, C_, cfg: ModelConfig, h_init=None):
    """Chunked SSD. x: (B,S,H,P) fp32; dt: (B,S,H) fp32 (already softplus'd);
    a: (H,) fp32 negative; B_/C_: (B,S,G,N) fp32.
    Returns (y (B,S,H,P) fp32, h_final (B,H,P,N) fp32)."""
    s = cfg.ssm
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    Q = min(s.chunk, S)
    S_orig = S
    if S % Q:
        # pad with dt=0 steps: decay exp(0)=1 and zero input => state unchanged
        pad = Q - S % Q
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        B_ = jnp.pad(B_, [(0, 0), (0, pad), (0, 0), (0, 0)])
        C_ = jnp.pad(C_, [(0, 0), (0, pad), (0, 0), (0, 0)])
        S = S + pad
    nc = S // Q
    hpg = H // G                                                 # heads per group

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = B_.reshape(Bb, nc, Q, G, N)
    Cc = C_.reshape(Bb, nc, Q, G, N)

    delta = dtc * a[None, None, None, :]                         # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(delta, axis=2)                              # inclusive

    # ---- intra-chunk (quadratic within chunk)
    # L[i,j] = exp(cum_i - cum_j) for j<=i else 0
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcqgn,bcjgn->bcqjg", Cc, Bc)                # (B,nc,Q,Q,G)
    CB = jnp.repeat(CB, hpg, axis=-1)                            # (B,nc,Q,Q,H)
    M = CB * L * dtc[:, :, None, :, :]                           # weight dt_j
    y_intra = jnp.einsum("bcqjh,bcjhp->bcqhp", M, xc)

    # ---- chunk-end states from local inputs
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtc               # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, hpg, axis=3)                             # (B,nc,Q,H,N)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w_end, Bh, xc)

    # ---- inter-chunk recurrence over nc (sequential scan)
    decay_chunk = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)
    if h_init is None:
        h_init = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(h, inp):
        dc, st = inp                                             # (B,H), (B,H,P,N)
        h_out = h                                                # state ENTERING chunk
        h = h * dc[:, :, None, None] + st
        return h, h_out

    h_final, h_entry = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(decay_chunk, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_entry = jnp.moveaxis(h_entry, 0, 1)                        # (B,nc,H,P,N)

    # ---- inter-chunk contribution: C_i * exp(cum_i) * h_entry
    Ch = jnp.repeat(Cc, hpg, axis=3)                             # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, h_entry, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bb, S, H, P)[:, :S_orig]
    return y, h_final


def mamba_forward(p: dict, u: jax.Array, cfg: ModelConfig,
                  conv_cache=None, ssd_state=None, return_state: bool = False):
    """Full-sequence Mamba2 block. u: (B,S,d). Returns y (B,S,d)
    [+ ((conv_x, conv_bc), ssd_state) if return_state]."""
    s = cfg.ssm
    di, G, N, H, P = cfg.d_inner, s.n_groups, s.state, cfg.ssm_heads, s.head_dim
    Bb, S, _ = u.shape
    cx, cbc = conv_cache if conv_cache is not None else (None, None)
    z, x, BC, dt, new_cx, new_cbc = _project(p, u, cfg, cx, cbc)
    B_, C_ = jnp.split(BC, 2, axis=-1)

    xf = x.astype(jnp.float32).reshape(Bb, S, H, P)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    Bf = B_.astype(jnp.float32).reshape(Bb, S, G, N)
    Cf = C_.astype(jnp.float32).reshape(Bb, S, G, N)

    y, h_final = ssd_chunked(xf, dtf, a, Bf, Cf, cfg, h_init=ssd_state)
    y = y + xf * p["D"][None, None, :, None]
    y = y.reshape(Bb, S, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, ((new_cx, new_cbc), h_final)
    return out


def mamba_decode(p: dict, u: jax.Array, conv_cache, ssd_state, cfg: ModelConfig):
    """One-token recurrent step. u: (B,1,d);
    conv_cache: ((B,W-1,di), (B,W-1,2GN)); ssd_state: (B,H,P,N) fp32.
    Returns (y (B,1,d), conv_cache, ssd_state)."""
    s = cfg.ssm
    di, G, N, H, P = cfg.d_inner, s.n_groups, s.state, cfg.ssm_heads, s.head_dim
    Bb = u.shape[0]
    cx, cbc = conv_cache
    z, x, BC, dt, new_cx, new_cbc = _project(p, u, cfg, cx, cbc)
    B_, C_ = jnp.split(BC, 2, axis=-1)

    xf = x.astype(jnp.float32).reshape(Bb, H, P)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).reshape(Bb, H)
    a = -jnp.exp(p["A_log"])
    Bf = B_.astype(jnp.float32).reshape(Bb, G, N)
    Cf = C_.astype(jnp.float32).reshape(Bb, G, N)
    hpg = H // G
    Bh = jnp.repeat(Bf, hpg, axis=1)                             # (B,H,N)
    Ch = jnp.repeat(Cf, hpg, axis=1)

    decay = jnp.exp(dtf * a[None, :])                            # (B,H)
    h = ssd_state * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dtf, xf, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch) + xf * p["D"][None, :, None]
    y = y.reshape(Bb, 1, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    return y @ p["out_proj"], (new_cx, new_cbc), h
