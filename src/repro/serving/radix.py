"""Radix prefix cache over KV pages (SGLang-RadixAttention-style, page
granularity): maps token-block prefixes to resident page ids so prefill can
skip recomputation — the mechanism whose locality SkyLB's routing protects.

Each node = one FULL page (page_size tokens), keyed by that page's token
tuple. Nodes hold the page id and a last-access stamp; pages referenced by
the tree carry one allocator ref, plus one per sequence currently using
them. Eviction walks refcount-1 leaves (tree-only refs) in LRU order.
"""
from __future__ import annotations

import itertools
from typing import Optional

from repro.serving.blocks import BlockAllocator

_clock = itertools.count()


class _Node:
    __slots__ = ("children", "page", "stamp", "parent", "key")

    def __init__(self, parent: Optional["_Node"], key, page: int = -1):
        self.children: dict[tuple, _Node] = {}
        self.page = page
        self.stamp = next(_clock)
        self.parent = parent
        self.key = key


class PagedRadixCache:
    def __init__(self, allocator: BlockAllocator, page_size: int):
        self.alloc = allocator
        self.page_size = page_size
        self.root = _Node(None, None)
        self.cached_pages = 0

    # ---------------------------------------------------------- lookup
    def match(self, tokens: tuple) -> tuple[int, list[int]]:
        """Longest full-page cached prefix. Returns (n_cached_tokens,
        page_ids). Does NOT take refs — call `take_refs` on admit."""
        node = self.root
        pages: list[int] = []
        ps = self.page_size
        for i in range(0, len(tokens) - ps + 1, ps):
            key = tuple(tokens[i:i + ps])
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = next(_clock)
            pages.append(child.page)
            node = child
        return len(pages) * ps, pages

    def take_refs(self, pages: list[int]) -> None:
        for p in pages:
            self.alloc.incref(p)

    # ---------------------------------------------------------- insert
    def insert(self, tokens: tuple, pages: list[int]) -> int:
        """Claim a finished sequence's FULL pages into the tree. Page ids in
        `pages` must line up with token blocks. For pages already present the
        caller's page is NOT claimed (dedup keeps the older copy). Returns
        number of pages newly claimed (each gains one tree ref)."""
        node = self.root
        ps = self.page_size
        claimed = 0
        for bi, i in enumerate(range(0, len(tokens) - ps + 1, ps)):
            if bi >= len(pages):
                break
            key = tuple(tokens[i:i + ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(node, key, pages[bi])
                node.children[key] = child
                self.alloc.incref(pages[bi])        # tree's own ref
                claimed += 1
                self.cached_pages += 1
            child.stamp = next(_clock)
            node = child
        return claimed

    # ---------------------------------------------------------- evict
    def evict(self, n_pages: int) -> int:
        """Drop up to n_pages LRU leaf pages whose only ref is the tree's.
        Returns pages actually freed."""
        freed = 0
        while freed < n_pages:
            victim = self._lru_evictable_leaf()
            if victim is None:
                break
            del victim.parent.children[victim.key]
            self.alloc.decref(victim.page)
            self.cached_pages -= 1
            freed += 1
        return freed

    def _lru_evictable_leaf(self) -> Optional[_Node]:
        best: Optional[_Node] = None
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children.values())
            elif self.alloc.refcount(nd.page) == 1:     # tree-only ref
                if best is None or nd.stamp < best.stamp:
                    best = nd
        return best

    def evictable_pages(self) -> int:
        n = 0
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if not nd.children and self.alloc.refcount(nd.page) == 1:
                n += 1
        return n

    def clear(self) -> None:
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            self.alloc.decref(nd.page)
        self.root = _Node(None, None)
        self.cached_pages = 0
