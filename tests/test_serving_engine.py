"""Serving engine: paged continuous batching vs the dense-cache oracle,
radix prefix reuse, allocator hygiene, SP-P probe semantics, router."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.routing import PrefixTreePolicy, make_policy
from repro.serving import (Engine, EngineConfig, GenRequest, InProcessRouter,
                           SamplingParams)

ECFG = EngineConfig(page_size=8, n_pages=64, max_batch=4, max_seq_len=256,
                    prefill_pad=16)


@pytest.fixture()
def engine(qwen_reduced, qwen_model_params):
    _, params = qwen_model_params
    return Engine(qwen_reduced, params, ECFG)


def _greedy_ref(model, params, prompt, n_new):
    toks = jnp.asarray([list(prompt)], jnp.int32)
    logits, cache = model.prefill(params, {"tokens": toks}, pad_to=64)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = model.decode(
            params, cache, {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
                            "positions": jnp.asarray([pos], jnp.int32)})
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return tuple(out)


def test_engine_matches_dense_oracle(engine, qwen_reduced, qwen_model_params):
    model, params = qwen_model_params
    rng = np.random.default_rng(0)
    prompts = [tuple(rng.integers(0, qwen_reduced.vocab, size=n).tolist())
               for n in (12, 23, 9)]
    res = engine.generate([GenRequest(prompt_tokens=p,
                                      sampling=SamplingParams(max_new_tokens=6))
                           for p in prompts])
    for p, r in zip(prompts, res):
        assert r.output_tokens == _greedy_ref(model, params, p, 6)


def test_radix_prefix_reuse_second_turn(engine, qwen_reduced,
                                        qwen_model_params):
    model, params = qwen_model_params
    rng = np.random.default_rng(1)
    p1 = tuple(rng.integers(0, qwen_reduced.vocab, size=20).tolist())
    r1 = engine.generate([GenRequest(prompt_tokens=p1,
                                     sampling=SamplingParams(max_new_tokens=6))])[0]
    p2 = p1 + r1.output_tokens
    r2 = engine.generate([GenRequest(prompt_tokens=p2,
                                     sampling=SamplingParams(max_new_tokens=4))])[0]
    # full pages of turn 1 must be reused
    assert r2.cached_tokens >= ((len(p1) + 6 - 1) // 8 - 1) * 8 > 0
    # and the result still matches the dense oracle
    assert r2.output_tokens == _greedy_ref(model, params, p2, 4)


def test_allocator_no_leaks(engine, qwen_reduced):
    rng = np.random.default_rng(2)
    free0 = engine.alloc.free_pages + engine.radix.cached_pages
    reqs = [GenRequest(prompt_tokens=tuple(
        rng.integers(0, qwen_reduced.vocab, size=15).tolist()),
        sampling=SamplingParams(max_new_tokens=5)) for _ in range(6)]
    engine.generate(reqs)
    # all pages either free or owned by the radix cache (refcount exactly 1)
    assert engine.alloc.free_pages + engine.radix.cached_pages == free0
    assert not engine.running and not engine.pending


def test_spp_probe_semantics(engine, qwen_reduced):
    rng = np.random.default_rng(3)
    assert engine.available() and engine.pending_count() == 0
    for i in range(3):
        engine.submit(GenRequest(
            prompt_tokens=tuple(rng.integers(0, qwen_reduced.vocab,
                                             size=10).tolist()),
            sampling=SamplingParams(max_new_tokens=4)))
    assert engine.pending_count() == 3 and not engine.available()
    engine.step()           # admits all (plenty of pages)
    assert engine.pending_count() == 0 and engine.available()
    engine.run_until_idle()


def test_engine_full_keeps_pending(qwen_reduced, qwen_model_params):
    _, params = qwen_model_params
    # 8 pages only => a single request (needs ~3 pages) fills fast
    tiny = EngineConfig(page_size=8, n_pages=8, max_batch=4,
                        max_seq_len=128, prefill_pad=16)
    eng = Engine(qwen_reduced, params, tiny)
    rng = np.random.default_rng(4)
    for _ in range(3):
        eng.submit(GenRequest(
            prompt_tokens=tuple(rng.integers(0, qwen_reduced.vocab,
                                             size=20).tolist()),
            sampling=SamplingParams(max_new_tokens=8)))
    eng.step()
    assert eng.pending_count() >= 1          # capacity-blocked => not admitted
    assert not eng.available()               # SP-P reports full
    eng.run_until_idle()
    assert eng.completions == 3              # but everything finishes


def test_stop_token(engine, qwen_reduced, qwen_model_params):
    model, params = qwen_model_params
    rng = np.random.default_rng(5)
    p = tuple(rng.integers(0, qwen_reduced.vocab, size=16).tolist())
    full = _greedy_ref(model, params, p, 8)
    # first position whose token hasn't occurred earlier (greedy on tiny
    # models repeats tokens, so full[k] may == full[0])
    k = next((i for i, t in enumerate(full) if t not in full[:i]), 0)
    stop = full[k]
    r = engine.generate([GenRequest(
        prompt_tokens=p, sampling=SamplingParams(max_new_tokens=8,
                                                 stop_token=stop))])[0]
    assert r.output_tokens == full[:k + 1]
    assert r.finish_reason.value == "stop"


def test_engine_rejects_non_transformer(qwen_model_params):
    from repro.configs import get_config
    _, params = qwen_model_params
    with pytest.raises(NotImplementedError):
        Engine(get_config("mamba2-780m").reduced(), params, ECFG)


def test_router_two_layer_spp(qwen_reduced, qwen_model_params):
    _, params = qwen_model_params
    router = InProcessRouter(remote_policy=make_policy("TRIE"))
    for region in ("us", "eu"):
        lb = router.add_region(region, PrefixTreePolicy())
        # us is tiny (fills instantly), eu has room
        n_pages = 12 if region == "us" else 64
        lb.add_engine(f"{region}-r0", Engine(
            qwen_reduced, params,
            EngineConfig(page_size=8, n_pages=n_pages, max_batch=2,
                         max_seq_len=128, prefill_pad=16)))
    rng = np.random.default_rng(6)
    # submit across probe windows (the unified RoutingCore refreshes
    # availability at heartbeats, like the simulator — a single-tick burst
    # would ride the optimistic between-probe budget instead)
    for i in range(5):
        router.submit("us", GenRequest(
            prompt_tokens=tuple(rng.integers(0, qwen_reduced.vocab,
                                             size=18).tolist()),
            sampling=SamplingParams(max_new_tokens=6)))
        router.step()
        router.step()
    router.run_until_idle()
    res = router.results()
    assert len(res) == 5
    assert router.lbs["us"].forwarded_out > 0     # spillover to eu happened
