"""Multi-tenant fairness & admission control (repro.tenancy): VTC queue
disciplines (charging, lift rule, weights), the router-level CRDT ledger,
deadline-aware shedding, SLO lanes, the heartbeat/wire plumbing that
carries tenant state across processes, and the deprecation shim mapping
the sim's legacy integer `priority` onto SLO classes."""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import pytest

from repro.core.simulator import ReplicaConfig, Request
from repro.core.system import ServingSystem
from repro.plane import wire
from repro.replica import CostModelBackend, ReplicaCore, ReplicaCoreConfig
from repro.routing import (PrefixTreePolicy, RoutingConfig, RoutingCore,
                           TargetView)
from repro.serving.request import GenRequest, SamplingParams
from repro.tenancy import (AdmissionParams, FCFSDiscipline, QueueDiscipline,
                           TenantLedger, VTCDiscipline, WeightedVTCDiscipline,
                           make_discipline, should_shed, tenant_of,
                           tenant_weight_of)


def _gen(rid, user, prompt, max_new=4, **kw):
    return GenRequest(prompt_tokens=tuple(prompt), rid=rid, user_id=user,
                      sampling=SamplingParams(max_new_tokens=max_new), **kw)


# ===================================================== queue disciplines

@dataclasses.dataclass
class _FakeReq:
    rid: int
    user_id: str
    tenant_weight: float = 1.0


@dataclasses.dataclass
class _FakeSeq:
    req: _FakeReq


def _pending(*tenants):
    return [_FakeSeq(_FakeReq(i, t)) for i, t in enumerate(tenants)]


def test_disciplines_satisfy_protocol():
    for d in (FCFSDiscipline(), VTCDiscipline(), WeightedVTCDiscipline()):
        assert isinstance(d, QueueDiscipline)


def test_fcfs_is_pure_noop():
    d = FCFSDiscipline()
    d.on_enqueue("a", 1)
    d.on_admit("a", 100, 50)
    d.on_tokens("a", 10)
    d.on_leave(1)
    assert d.select(_pending("b", "a", "a")) == 0
    assert d.counters() == {}


def test_make_discipline():
    assert make_discipline("fcfs").name == "fcfs"
    assert make_discipline("vtc").name == "vtc"
    assert make_discipline("wvtc").name == "wvtc"
    assert make_discipline("vtc", cache_discount=0.5).cache_discount == 0.5
    with pytest.raises(ValueError):
        make_discipline("priority")


def test_tenant_helpers():
    assert tenant_of(_FakeReq(0, "alice")) == "alice"
    assert tenant_of(_FakeReq(0, "")) == "_anon"       # anonymous pools
    assert tenant_of(object()) == "_anon"
    assert tenant_weight_of(_FakeReq(0, "a", 2.5)) == 2.5
    assert tenant_weight_of(_FakeReq(0, "a", 0.0)) == 1.0    # non-positive
    assert tenant_weight_of(_FakeReq(0, "a", -3.0)) == 1.0
    assert tenant_weight_of(object()) == 1.0                 # absent


def test_vtc_charging_with_cache_discount():
    d = VTCDiscipline(cache_discount=0.25)
    d.on_enqueue("a", 1)
    d.on_admit("a", 100, 40)          # uncached full price, cached at 0.25
    assert d.counters()["a"] == pytest.approx(110.0)
    d.on_tokens("a", 8)               # one unit per decoded token
    assert d.counters()["a"] == pytest.approx(118.0)


def test_vtc_select_least_served_fcfs_within_ties():
    d = VTCDiscipline()
    pend = _pending("a", "b", "a")
    for seq in pend:
        d.on_enqueue(seq.req.user_id, seq.req.rid)
    assert d.select(pend) == 0        # all zero: strict < keeps FCFS order
    d.on_tokens("a", 10)
    assert d.select(pend) == 1        # b is now the least-served tenant
    d.on_tokens("b", 20)
    assert d.select(pend) == 0        # a back in front, earliest request


def test_vtc_lift_rule_no_banked_credit():
    d = VTCDiscipline()
    d.on_enqueue("a", 1)
    d.on_admit("a", 50, 0)
    # a newcomer while "a" is active enters at the active floor, not zero
    d.on_enqueue("b", 2)
    assert d.counters()["b"] == pytest.approx(50.0)
    # "a" goes idle at 50; "b" is served on to 80; "a" must RE-ENTER at 80
    # (an idle tenant does not bank credit while others are served)
    d.on_leave(1)
    d.on_tokens("b", 30)
    d.on_enqueue("a", 3)
    assert d.counters()["a"] == pytest.approx(80.0)
    # ...but a tenant ahead of the floor keeps its own (monotone) counter
    d.on_tokens("a", 100)             # a at 180
    d.on_leave(3)
    d.on_enqueue("a", 4)
    assert d.counters()["a"] == pytest.approx(180.0)


def test_vtc_on_leave_idempotent():
    d = VTCDiscipline()
    d.on_enqueue("a", 1)
    d.on_leave(1)
    d.on_leave(1)                     # second retire of the same rid: no-op
    d.on_leave(999)                   # unknown rid: no-op
    assert d._active.get("a") == set()


def test_weighted_vtc_charges_inverse_weight():
    w = WeightedVTCDiscipline()
    w.on_enqueue("a", 1, weight=2.0)
    w.on_admit("a", 10, 0, weight=2.0)
    assert w.counters()["a"] == pytest.approx(5.0)    # 10 tokens / weight 2
    w.on_tokens("a", 4, weight=2.0)
    assert w.counters()["a"] == pytest.approx(7.0)
    # the UNweighted discipline ignores weights entirely
    u = VTCDiscipline()
    u.on_enqueue("a", 1, weight=2.0)
    u.on_admit("a", 10, 0, weight=2.0)
    assert u.counters()["a"] == pytest.approx(10.0)


# ======================================================== tenant ledger

def test_ledger_charge_and_weight():
    led = TenantLedger()
    led.charge("a", 12.0)
    led.charge("a", 8.0)
    led.charge("b", 10.0, weight=2.0)
    assert led.snapshot() == {"a": 20.0, "b": 5.0}
    assert led.mean() == pytest.approx(12.5)


def test_ledger_merge_is_monotone_max():
    led = TenantLedger()
    led.charge("a", 20.0)
    led.merge({"a": 5.0, "b": 7.0})   # stale peer view of "a" must not win
    assert led.snapshot() == {"a": 20.0, "b": 7.0}
    led.merge(None)                   # absent heartbeat field: no-op
    led.merge({})
    assert led.snapshot() == {"a": 20.0, "b": 7.0}


def test_ledger_merge_order_independent():
    """CRDT join: any merge order over the same peer snapshots converges."""
    snaps = [{"a": 3.0, "b": 9.0}, {"a": 7.0, "c": 1.0}, {"b": 2.0}]
    x, y = TenantLedger(), TenantLedger()
    for s in snaps:
        x.merge(s)
    for s in reversed(snaps):
        y.merge(s)
    assert x.snapshot() == y.snapshot() == {"a": 7.0, "b": 9.0, "c": 1.0}


def test_ledger_is_heavy():
    led = TenantLedger()
    led.charge("a", 1000.0)
    assert not led.is_heavy("a")      # a lone tenant is just the workload
    led.charge("b", 10.0)
    led.charge("c", 10.0)
    assert led.is_heavy("a")          # 1000 > 2 * mean(340)
    assert not led.is_heavy("b") and not led.is_heavy("unknown")
    assert not led.is_heavy("a", factor=10.0)


# ===================================================== admission control

def test_should_shed():
    p = AdmissionParams()
    assert not should_shed(1000, 50, 50, None, p)     # no deadline: never
    assert should_shed(48, 40, 0, 0.5, p)             # 40*0.05s queue >> 0.5s
    assert not should_shed(17, 0, 0, 10.0, p)         # idle replica: easily
    # slack_frac scales the verdict threshold
    assert should_shed(48, 4, 0, 1.0, AdmissionParams(slack_frac=0.1))
    assert not should_shed(48, 4, 0, 1.0, AdmissionParams(slack_frac=1.0))


# ================================================== replica-core fairness

_CORE = dict(page_size=8, n_pages=64, max_batch=1, record_decisions=True)


def _tenant_trace(core: ReplicaCore) -> None:
    """Two of tenant a's requests queued ahead of tenant b's (disjoint
    prompts; max_batch=1 serializes admissions)."""
    core.submit(_gen(1, "a", range(0, 16)))
    core.submit(_gen(2, "a", range(100, 116)))
    core.submit(_gen(3, "b", range(200, 216)))
    while core.running or core.pending:
        core.begin_step()
        core.finish_step()


def test_core_vtc_admits_least_served_tenant_first():
    core = ReplicaCore(ReplicaCoreConfig(discipline="vtc", **_CORE),
                       CostModelBackend())
    _tenant_trace(core)
    admits = [d[1] for d in core.decisions if d[0] == "admit"]
    assert admits == [1, 3, 2]        # b jumps a's backlog after a is charged
    # every admission carries its fairness record, tagged with the tenant
    assert [d for d in core.decisions if d[0] == "admit_fair"] == \
        [("admit_fair", 1, "a"), ("admit_fair", 3, "b"),
         ("admit_fair", 2, "a")]
    # counters: 16 uncached prefill + 3 decode appends per request (the
    # first of the 4 new tokens comes out of the prefill itself), monotone
    assert core.tenant_counters() == {"a": pytest.approx(38.0),
                                      "b": pytest.approx(19.0)}


def test_core_default_fcfs_stream_has_no_tenancy_kinds():
    """With the default discipline the decision stream must look exactly
    like the pre-tenancy core: FCFS order, no admit_fair/shed records, no
    counters (this is what keeps the replica parity suites byte-stable)."""
    core = ReplicaCore(ReplicaCoreConfig(**_CORE), CostModelBackend())
    _tenant_trace(core)
    admits = [d[1] for d in core.decisions if d[0] == "admit"]
    assert admits == [1, 2, 3]
    kinds = {d[0] for d in core.decisions}
    assert "admit_fair" not in kinds and "shed" not in kinds
    assert core.tenant_counters() == {}
    assert core.sheds == 0


def test_core_shed_deadline():
    core = ReplicaCore(ReplicaCoreConfig(shed_deadline=True, **_CORE),
                       CostModelBackend())
    for i in range(10):               # a deep pending queue (max_batch=1)
        core.submit(_gen(i, "a", range(i * 100, i * 100 + 16), max_new=8))
    core.begin_step()
    assert len(core.pending) == 9
    core.submit(_gen(99, "b", range(5000, 5016), deadline_s=0.05))
    assert core.sheds == 1            # 9 * 50ms queue wait >> 50ms deadline
    assert ("shed", 99) in core.decisions
    assert all(s.req.rid != 99 for s in core.pending)
    plan = core.begin_step()          # the host resolves plan.shed
    assert [s.req.rid for s in plan.shed] == [99]
    assert plan.shed[0].error and "deadline" in plan.shed[0].error
    # deadline-free requests are NEVER shed, no matter the backlog
    core.submit(_gen(100, "b", range(6000, 6016)))
    assert core.sheds == 1


# ===================================================== routing-core level

@dataclasses.dataclass
class _RReq:
    rid: int
    user_id: str = "u"
    session_key: str = "u"
    prompt_tokens: tuple = ()
    output_len: int = 8
    tenant_weight: float = 1.0
    slo_class: str = "standard"
    deadline_s: Optional[float] = None
    forwarded: bool = False


class _FixtureTransport:
    def __init__(self):
        self.sent: list[tuple] = []
        self.sheds: list[int] = []

    def now(self) -> float:
        return 0.0

    def target_alive(self, tid: str) -> bool:
        return True

    def peer_alive(self, pid: str) -> bool:
        return True

    def deliver(self, req, tid: str) -> None:
        self.sent.append(("local", req.rid, tid))

    def forward(self, req, pid: str) -> None:
        self.sent.append(("forward", req.rid, pid))

    def steal_request(self, pid: str, n: int) -> None:
        pass

    def shed(self, req) -> None:
        self.sheds.append(req.rid)


def _router(**cfg_kw) -> tuple[RoutingCore, _FixtureTransport]:
    t = _FixtureTransport()
    core = RoutingCore("lb-us", PrefixTreePolicy(),
                       remote_policy=PrefixTreePolicy(),
                       cfg=RoutingConfig(record_decisions=True, **cfg_kw),
                       transport=t)
    return core, t


def test_router_heavy_tenant_loses_cache_affinity():
    prefix = tuple(range(40))
    routed = {}
    for fairness in (False, True):
        core, t = _router(fairness=fairness)
        # warm r0 with the tenant's prefix while it is the only replica
        core.refresh_local([TargetView(id="r0")])
        core.on_request(_RReq(rid=0, user_id="H", prompt_tokens=prefix))
        # r1 appears idle; r0 (the warm one) carries load
        core.refresh_local([TargetView(id="r0", outstanding=2),
                            TargetView(id="r1")])
        core.tenants.charge("H", 1000.0)      # H dwarfs the others
        core.tenants.charge("L1", 10.0)
        core.tenants.charge("L2", 10.0)
        core.on_request(_RReq(rid=1, user_id="H", prompt_tokens=prefix))
        routed[fairness] = t.sent[-1]
        if fairness:
            assert ("fair", 1, "H") in core.decisions
        else:
            assert all(d[0] != "fair" for d in core.decisions)
    # affinity holds without fairness; a HEAVY tenant is spread least-load
    assert routed[False] == ("local", 1, "r0")
    assert routed[True] == ("local", 1, "r1")


def test_router_light_tenant_keeps_affinity_under_fairness():
    prefix = tuple(range(40))
    core, t = _router(fairness=True)
    core.refresh_local([TargetView(id="r0")])
    core.on_request(_RReq(rid=0, user_id="L1", prompt_tokens=prefix))
    core.refresh_local([TargetView(id="r0", outstanding=2),
                        TargetView(id="r1")])
    core.tenants.charge("H", 1000.0)
    core.tenants.charge("L1", 10.0)
    core.tenants.charge("L2", 10.0)
    core.on_request(_RReq(rid=1, user_id="L1", prompt_tokens=prefix))
    assert t.sent[-1] == ("local", 1, "r0")   # trie affinity intact
    assert all(d[0] != "fair" for d in core.decisions)


def test_router_charges_expected_tokens_on_dispatch():
    core, _ = _router(fairness=True)
    core.refresh_local([TargetView(id="r0")])
    core.on_request(_RReq(rid=0, user_id="a", prompt_tokens=(1, 2, 3, 4),
                          output_len=8))
    assert core.tenants.snapshot() == {"a": pytest.approx(12.0)}
    # weighted tenants are charged 1/weight per expected token
    core.on_request(_RReq(rid=1, user_id="b", prompt_tokens=(5, 6, 7, 8),
                          output_len=8, tenant_weight=2.0))
    assert core.tenants.snapshot()["b"] == pytest.approx(6.0)
    # fairness off: the ledger never moves
    off, _ = _router()
    off.refresh_local([TargetView(id="r0")])
    off.on_request(_RReq(rid=0, user_id="a", prompt_tokens=(1, 2)))
    assert off.tenants.snapshot() == {}
    assert off.tenant_snapshot() is None      # and heartbeats stay lean


def test_router_slo_lanes_order():
    classes = ["standard", "latency", "standard", "interactive", "batch"]
    for lanes, want in ((False, [0, 1, 2, 3, 4]), (True, [1, 3, 0, 2, 4])):
        core, t = _router(slo_lanes=lanes)
        for rid, sc in enumerate(classes):    # no capacity yet: all queue
            core.on_request(_RReq(rid=rid, slo_class=sc))
        assert [r.rid for r in core.queue] == want
        core.refresh_local([TargetView(id="r0")])   # capacity: FIFO drain
        assert [r for (k, r, _) in t.sent if k == "local"] == want


def test_router_admission_sheds_doomed_head():
    core, t = _router(admission=True)
    core.refresh_local([TargetView(id="r0", pending=40)])
    core.on_request(_RReq(rid=0, deadline_s=0.5))   # 40*50ms wait >> 0.5s
    assert t.sheds == [0] and t.sent == []
    assert core.sheds == 1
    assert ("shed", 0, "lb-us") in core.decisions
    # no deadline, same backlog: dispatches normally
    core.on_request(_RReq(rid=1))
    assert t.sent == [("local", 1, "r0")]
    # admission off: deadline or not, nothing sheds
    off, t_off = _router()
    off.refresh_local([TargetView(id="r0", pending=40)])
    off.on_request(_RReq(rid=0, deadline_s=0.5))
    assert t_off.sheds == [] and off.sheds == 0


def test_router_heartbeats_merge_tenant_counters():
    core, _ = _router(fairness=True)
    core.refresh_local([TargetView(id="r0", tenant_counters={"a": 5.0})])
    core.peer_added("eu")
    core.refresh_remote([TargetView(id="eu", n_replicas=1,
                                    tenant_counters={"a": 3.0, "b": 7.0})])
    assert core.tenants.snapshot() == {"a": 5.0, "b": 7.0}   # max-merge
    assert core.tenant_snapshot() == {"a": 5.0, "b": 7.0}
    # fairness off: counters in heartbeats are ignored, not merged
    off, _ = _router()
    off.refresh_local([TargetView(id="r0", tenant_counters={"a": 5.0})])
    assert off.tenants.snapshot() == {}


# ========================================================= wire plumbing

@pytest.fixture(params=["msgpack", "json"])
def codec(request, monkeypatch):
    if request.param == "msgpack":
        pytest.importorskip("msgpack")
        monkeypatch.delenv("REPRO_PLANE_CODEC", raising=False)
    else:
        monkeypatch.setenv("REPRO_PLANE_CODEC", "json")
    return request.param


def _roundtrip(msg: dict) -> dict:
    frame = wire.pack(msg)
    return wire.unpack(frame[4:])     # strip the length prefix


def test_wire_request_carries_tenant_weight(codec):
    req = GenRequest(prompt_tokens=(1, 2, 3), rid=7, user_id="acme",
                     tenant_weight=2.5, slo_class="interactive",
                     sampling=SamplingParams(max_new_tokens=4))
    back = wire.decode_request(_roundtrip(wire.encode_request(req)))
    assert back.tenant_weight == 2.5
    assert back.user_id == "acme" and back.slo_class == "interactive"
    # frames from peers predating the field decode to the default
    legacy = wire.encode_request(req)
    del legacy["tenant_weight"]
    assert wire.decode_request(_roundtrip(legacy)).tenant_weight == 1.0


def test_wire_view_carries_tenant_counters(codec):
    view = TargetView(id="r0", pending=3,
                      tenant_counters={"a": 2.5, "b": 7.0})
    back = wire.decode_view(_roundtrip(wire.encode_view(view)))
    assert back.tenant_counters == {"a": 2.5, "b": 7.0}
    assert back.pending == 3
    # no ledger -> no key on the wire (lean frames), default on decode
    bare = wire.encode_view(TargetView(id="r1"))
    assert "tenant_counters" not in bare
    assert wire.decode_view(_roundtrip(bare)).tenant_counters is None


# =============================================== sim priority deprecation

def _sim_req(**kw) -> Request:
    return Request(rid=1, user_id="u", session_key="u", region="us",
                   prompt_tokens=(1, 2), output_len=2, **kw)


@pytest.mark.parametrize("priority,expect", [(2, "latency"), (3, "latency"),
                                             (1, "interactive"),
                                             (-1, "batch")])
def test_sim_priority_deprecated_maps_to_slo_lane(priority, expect):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = _sim_req(priority=priority)
    assert len(w) == 1 and issubclass(w[0].category, DeprecationWarning)
    assert "deprecated" in str(w[0].message)
    assert r.slo_class == expect
    assert r.priority == priority     # replica scheduling unchanged


def test_sim_priority_default_or_explicit_class_no_warning():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        a = _sim_req()                                  # defaults: silent
        b = _sim_req(priority=2, slo_class="latency")   # both set: silent
    assert w == []
    assert a.slo_class == "standard" and b.slo_class == "latency"


# ====================================================== sim end-to-end

def test_sim_vtc_fairness_end_to_end():
    sys = ServingSystem(
        "bp", {"us": 2},
        replica_cfg=ReplicaConfig(kv_budget=2048, discipline="vtc"),
        cfg_overrides={"fairness": True})
    sys.add_tenant_load("us", rate=20.0, until=4.0, n_tenants=4, alpha=1.6,
                        heavy_tenants=1, heavy_prefix_len=128, prompt_len=32,
                        light_prefix_len=16, output_len=16)
    s = sys.run(until=20.0)
    assert s["requests"] > 0 and s["unresolved"] == 0 and s["shed"] == 0
    # replica VTC counters fed the router ledger through heartbeats
    lb = sys.lbs["lb-us"]
    assert lb.core.tenants.snapshot()
    per_tenant = sys.metrics.per_tenant()
    assert len(per_tenant) >= 2
    assert all(g["n"] > 0 and g["p90"] >= g["p50"] >= 0
               for g in per_tenant.values())


def test_sim_shed_end_to_end():
    sys = ServingSystem(
        "bp", {"us": 1},
        replica_cfg=ReplicaConfig(kv_budget=2048, shed_deadline=True),
        cfg_overrides={"admission": True, "slo_lanes": True})
    sys.add_tenant_load("us", rate=80.0, until=4.0, deadline_s=0.3,
                        n_tenants=4, alpha=1.6, heavy_tenants=1,
                        heavy_prefix_len=128, prompt_len=32,
                        light_prefix_len=16, output_len=16)
    s = sys.run(until=20.0)
    assert s["shed"] > 0              # hopeless requests refused up-front
    assert s["unresolved"] == 0       # every shed resolved exactly once
    assert len(sys.metrics.shed) == s["shed"]
    assert all(r.finish_reason == "shed" for r in sys.metrics.shed)


def test_metrics_grouped_percentiles_shared_impl():
    sys = ServingSystem("bp", {"us": 1},
                        replica_cfg=ReplicaConfig(kv_budget=2048))
    sys.add_tenant_load("us", rate=15.0, until=3.0, n_tenants=3, alpha=1.2,
                        heavy_tenants=1, heavy_prefix_len=64, prompt_len=24,
                        light_prefix_len=16, output_len=8)
    sys.run(until=15.0)
    m = sys.metrics
    # the three breakdowns are the SAME grouped implementation keyed
    # differently — totals must agree across all of them
    n_done = sum(g["n"] for g in m.per_tenant().values())
    assert n_done > 0
    assert sum(g["n"] for g in m.per_region().values()) == n_done
    assert sum(g["n"] for g in m.per_slo_class().values()) == n_done
    assert set(m.per_region()) == {"us"}
    assert set(m.per_slo_class()) == {"standard"}
    whole = m.grouped_percentiles(lambda r: "all", ps=(50, 90, 99))
    assert whole["all"]["n"] == n_done
    assert whole["all"]["p99"] >= whole["all"]["p50"]
